"""Benchmark harness — one entry per paper table/figure plus kernel
microbenchmarks. Prints ``name,us_per_call,derived`` CSV.

Usage:
    PYTHONPATH=src python -m benchmarks.run              # default scale
    PYTHONPATH=src python -m benchmarks.run --scale quick
    PYTHONPATH=src python -m benchmarks.run --only fig5,kernels
    PYTHONPATH=src python -m benchmarks.run --sequential # pre-sweep loop
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="default",
                    choices=["quick", "default", "full"])
    ap.add_argument("--only", default="",
                    help="comma-separated subset of suites (see error "
                         "message or source for the list)")
    ap.add_argument("--sequential", action="store_true",
                    help="run figure grids cell-by-cell (the pre-sweep "
                         "baseline) instead of the batched sweep engine")
    args = ap.parse_args()

    from benchmarks import figures, kernel_bench

    scale, seq = args.scale, args.sequential
    suites = {
        "fig1": lambda: figures.fig1_link_utilization(scale, seq),
        "fig5": lambda: figures.fig5_testbed_fct(scale, seq),
        "fig6": lambda: figures.fig6_fidelity(scale, seq),
        "fig7_8": lambda: figures.fig7_8_large_scale(scale, seq),
        "fig9": lambda: figures.fig9_workloads(scale, seq),
        "fig10": lambda: figures.fig10_cc_orthogonality(scale, seq),
        "fig11": lambda: figures.fig11_ablations(scale, seq),
        "failover": lambda: figures.failover_bench(scale, seq),
        "staleness": lambda: figures.staleness_ablation(scale, seq),
        "scenarios": lambda: figures.scenarios_bench(scale, seq),
        "kernels": kernel_bench.all_benches,
    }
    wanted = [s for s in args.only.split(",") if s] or list(suites)
    unknown = sorted(set(wanted) - set(suites))
    if unknown:
        sys.exit(f"error: unknown suite(s): {', '.join(unknown)}\n"
                 f"valid suites: {', '.join(suites)}")

    print("name,us_per_call,derived")
    ok = True
    for name in wanted:
        try:
            for row, us, derived in suites[name]():
                print(f"{row},{us:.0f},{derived}")
                sys.stdout.flush()
        except Exception:
            ok = False
            traceback.print_exc()
            print(f"{name},0,ERROR")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
