"""Benchmark harness — one entry per paper table/figure plus kernel
microbenchmarks. Prints ``name,us_per_call,derived`` CSV.

Usage:
    PYTHONPATH=src python -m benchmarks.run              # default scale
    PYTHONPATH=src python -m benchmarks.run --scale quick
    PYTHONPATH=src python -m benchmarks.run --only fig5,kernels
    PYTHONPATH=src python -m benchmarks.run --engine packet   # packet backend
    PYTHONPATH=src python -m benchmarks.run --engine both     # fluid + packet
    PYTHONPATH=src python -m benchmarks.run --list       # suite table, no runs
    PYTHONPATH=src python -m benchmarks.run --sequential # pre-sweep loop
"""
from __future__ import annotations

import argparse
import sys
import traceback

# suites that pick their own engine(s): fidelity, fig_multipath, fig_geo
# and fig_training run both backends by design; kernels have no
# simulation engine
_ENGINE_AGNOSTIC = ("fidelity", "fig_multipath", "fig_geo", "fig_training",
                    "kernels")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="default",
                    choices=["quick", "default", "full"])
    ap.add_argument("--only", default="",
                    help="comma-separated subset of suites (see --list)")
    ap.add_argument("--engine", default="fluid",
                    choices=["fluid", "packet", "both"],
                    help="simulation backend for the figure grids; 'both' "
                         "runs every selected suite once per engine "
                         "(packet rows are tagged fig*[packet])")
    ap.add_argument("--list", action="store_true",
                    help="print the suite table and exit without running")
    ap.add_argument("--sequential", action="store_true",
                    help="run figure grids cell-by-cell (the pre-sweep "
                         "baseline) instead of the batched sweep engine")
    ap.add_argument("--bench", action="store_true",
                    help="run the wall-clock regression guard instead of "
                         "figure suites: writes benchmarks/out/"
                         "BENCH_netsim.json and soft-warns on rows >1.3x "
                         "the committed baseline (see benchmarks.perf)")
    args = ap.parse_args()

    if args.bench:
        from benchmarks import perf
        perf.run_bench()
        return

    from benchmarks import figures, kernel_bench

    def kernels(scale, seq, eng):
        """Pallas/jnp kernel microbenchmarks (engine-agnostic)."""
        del scale, seq, eng
        return kernel_bench.all_benches()

    scale, seq = args.scale, args.sequential
    suites = {
        "fig1": figures.fig1_link_utilization,
        "fig5": figures.fig5_testbed_fct,
        "fig6": figures.fig6_fidelity,
        "fig7_8": figures.fig7_8_large_scale,
        "fig9": figures.fig9_workloads,
        "fig10": figures.fig10_cc_orthogonality,
        "fig11": figures.fig11_ablations,
        "failover": figures.failover_bench,
        "fig_large": figures.fig_large,
        "fig_multipath": figures.fig_multipath,
        "fig_geo": figures.fig_geo,
        "fig_training": figures.fig_training,
        "staleness": figures.staleness_ablation,
        "scenarios": figures.scenarios_bench,
        "fidelity": figures.fidelity_bench,
        "kernels": kernels,
    }

    if args.list:
        print(f"{'suite':<10} description")
        for name, fn in suites.items():
            doc = (fn.__doc__ or "").strip().splitlines()
            print(f"{name:<10} {doc[0] if doc else ''}")
        return

    wanted = [s for s in args.only.split(",") if s] or list(suites)
    unknown = sorted(set(wanted) - set(suites))
    if unknown:
        sys.exit(f"error: unknown suite(s): {', '.join(unknown)}\n"
                 f"valid suites: {', '.join(suites)}")

    engines = ["fluid", "packet"] if args.engine == "both" else [args.engine]

    print("name,us_per_call,derived")
    ok = True
    for name in wanted:
        for eng in engines:
            # engine-agnostic suites run exactly once per invocation
            if name in _ENGINE_AGNOSTIC and eng != engines[0]:
                continue
            try:
                for row, us, derived in suites[name](scale, seq, eng):
                    print(f"{row},{us:.0f},{derived}")
                    sys.stdout.flush()
            except Exception:
                ok = False
                traceback.print_exc()
                tag = name if eng == "fluid" else f"{name}[{eng}]"
                print(f"{tag},0,ERROR")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
