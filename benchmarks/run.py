"""Benchmark harness — one entry per paper table/figure plus kernel
microbenchmarks. Prints ``name,us_per_call,derived`` CSV.

Usage:
    PYTHONPATH=src python -m benchmarks.run              # default scale
    PYTHONPATH=src python -m benchmarks.run --scale quick
    PYTHONPATH=src python -m benchmarks.run --only fig5,kernels
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", default="default",
                    choices=["quick", "default", "full"])
    ap.add_argument("--only", default="",
                    help="comma-separated subset: fig1,fig5,fig6,fig7_8,"
                         "fig9,fig10,fig11,failover,kernels")
    args = ap.parse_args()

    from benchmarks import figures, kernel_bench

    suites = {
        "fig1": lambda: figures.fig1_link_utilization(args.scale),
        "fig5": lambda: figures.fig5_testbed_fct(args.scale),
        "fig6": lambda: figures.fig6_fidelity(args.scale),
        "fig7_8": lambda: figures.fig7_8_large_scale(args.scale),
        "fig9": lambda: figures.fig9_workloads(args.scale),
        "fig10": lambda: figures.fig10_cc_orthogonality(args.scale),
        "fig11": lambda: figures.fig11_ablations(args.scale),
        "failover": lambda: figures.failover_bench(args.scale),
        "kernels": kernel_bench.all_benches,
    }
    wanted = [s for s in args.only.split(",") if s] or list(suites)

    print("name,us_per_call,derived")
    ok = True
    for name in wanted:
        try:
            for row, us, derived in suites[name]():
                print(f"{row},{us:.0f},{derived}")
                sys.stdout.flush()
        except Exception:
            ok = False
            traceback.print_exc()
            print(f"{name},0,ERROR")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
