"""Wall-clock regression guard (``benchmarks.run --bench``).

Times the cost centers a refactor is most likely to slow down — world
build + flow generation, the fluid scan, and the packet scan — at quick
scale on the 8-DC testbed AND on the fig_geo operating point (the 20-DC
geo world with a diurnal schedule, whose haversine/schedule/thinning
layers are new cost centers), plus the kernel microbenchmarks. Writes
``benchmarks/out/BENCH_netsim.json`` and mirrors it to the repo-root
``BENCH_netsim.json`` — the root copy is *committed*, so the perf
trajectory travels with the history instead of dying with each CI
artifact. Against the committed
``benchmarks/BENCH_netsim.baseline.json`` any row slower than
``WARN_RATIO`` x baseline prints a ``BENCH-WARN`` line — a *soft* signal
(CI boxes are noisy; the JSON artifact is the durable record), never a
build failure.

The scan timings are split into ``*_compile`` (first call: trace + XLA
compile) and ``*_run`` (steady-state re-execution), because a refactor
can regress either independently — e.g. extra decision branches mostly
show up in compile time, per-step state bloat in run time.
"""
from __future__ import annotations

import json
import os
import platform
import time
from typing import Dict

import jax

from repro.netsim import engine as enginemod
from repro.netsim.experiment import ExpSpec, build_experiment, build_world

OUT = os.path.join(os.path.dirname(__file__), "out")
ROOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_netsim.json")
BASELINE = os.path.join(os.path.dirname(__file__),
                        "BENCH_netsim.baseline.json")
WARN_RATIO = 1.3

_SPEC = dict(topology="testbed8", load=0.4, duration_us=300_000, seed=1)
# fig_geo quick operating point (shorter horizon: the guard times the
# machinery — geo world build, schedule thinning, geo-scale scans — not
# the full figure)
_GEO_SPEC = dict(topology="geo:dcs=20,chords=10", load=0.43, bg_load=0.1,
                 duration_us=60_000, seed=9, cap_scale=0.0625,
                 load_sched="diurnal:amp=0.8,segs=24")
# cosim cost centers (shorter horizon again): the model-config resolve +
# plan build + overlay path, then the fluid scan with the collective
# rows in the flow table
_COSIM_SPEC = dict(topology="wan2000:dcs=8,segs=2,chords=4", load=0.5,
                   bg_load=0.1, duration_us=60_000, seed=9,
                   cap_scale=0.0625, cosim_model="qwen3-4b",
                   cosim_iters=4)


def _scan_times(engine: str, spec_kw: Dict = _SPEC,
                prefix: str = "") -> Dict[str, float]:
    spec = ExpSpec(engine=engine, policy="lcmp", **spec_kw)
    _, table, flows, cfg = build_experiment(spec)
    eng = enginemod.get_engine(engine)
    arrs, st = eng.build(table, flows, cfg)
    t0 = time.perf_counter()
    jax.block_until_ready(eng.run(arrs, st, cfg))
    compile_us = (time.perf_counter() - t0) * 1e6
    runs = []
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(eng.run(arrs, st, cfg))
        runs.append((time.perf_counter() - t0) * 1e6)
    return {f"{prefix}{engine}_scan_compile": compile_us,
            f"{prefix}{engine}_scan_run": min(runs)}


def collect() -> Dict[str, float]:
    from benchmarks import kernel_bench
    rows: Dict[str, float] = {}
    build_world.cache_clear()          # time a cold world build
    t0 = time.perf_counter()
    build_experiment(ExpSpec(engine="fluid", policy="lcmp", **_SPEC))
    rows["build_world_and_flows"] = (time.perf_counter() - t0) * 1e6
    rows.update(_scan_times("fluid"))
    rows.update(_scan_times("packet"))
    # sanitizer cost center: the same scans under the checkify
    # physics-invariant program (repro.netsim.sanitize) — the debug-mode
    # overhead must stay visible so `checks=1` remains a usable knob
    sanitize_spec = dict(_SPEC, checks=1)
    rows.update(_scan_times("fluid", sanitize_spec, prefix="sanitize_"))
    rows.update(_scan_times("packet", sanitize_spec, prefix="sanitize_"))
    # fig_geo cost centers: cold geo world (haversine + span expansion +
    # path enumeration) with a diurnal schedule (thinned arrivals), then
    # the fluid scan at geo scale
    build_world.cache_clear()
    t0 = time.perf_counter()
    build_experiment(ExpSpec(engine="fluid", policy="lcmp", **_GEO_SPEC))
    rows["geo_build_world_and_sched_flows"] = (time.perf_counter() - t0) * 1e6
    rows.update(_scan_times("fluid", _GEO_SPEC, prefix="geo_"))
    # cosim cost centers: configs registry resolve + bucket-plan build +
    # overlay merge (cold caches), then the fluid scan over the merged
    # flow table
    build_world.cache_clear()
    from repro.cosim.workload import _smoke_param_count
    _smoke_param_count.cache_clear()
    t0 = time.perf_counter()
    build_experiment(ExpSpec(engine="fluid", policy="lcmp", **_COSIM_SPEC))
    rows["cosim_plan_and_overlay_flows"] = (time.perf_counter() - t0) * 1e6
    rows.update(_scan_times("fluid", _COSIM_SPEC, prefix="cosim_"))
    for name, us, _ in kernel_bench.all_benches():
        rows[name] = us               # rows already carry the kernel/ tag
    return rows


def run_bench() -> None:
    rows = collect()
    os.makedirs(OUT, exist_ok=True)
    report = {
        "meta": {"platform": platform.platform(),
                 "python": platform.python_version(),
                 "jax": jax.__version__,
                 "spec": _SPEC},
        "rows_us": rows,
    }
    path = os.path.join(OUT, "BENCH_netsim.json")
    for p in (path, ROOT):           # root copy is committed (trajectory)
        with open(p, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        print(f"bench: wrote {p}")
    if not os.path.exists(BASELINE):
        print("bench: no committed baseline, skipping comparison")
        return
    with open(BASELINE) as f:
        base = json.load(f)["rows_us"]
    for name, us in sorted(rows.items()):
        ref = base.get(name)
        if ref is None:
            print(f"bench: {name}: {us:.0f}us (no baseline row)")
            continue
        ratio = us / ref if ref > 0 else float("inf")
        flag = (f"  BENCH-WARN >{WARN_RATIO:g}x baseline"
                if ratio > WARN_RATIO else "")
        print(f"bench: {name}: {us:.0f}us vs {ref:.0f}us "
              f"({ratio:.2f}x){flag}")


if __name__ == "__main__":
    run_bench()
