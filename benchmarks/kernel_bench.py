"""Microbenchmarks for the Pallas kernels (interpret mode on CPU — the
numbers are semantics-validation throughput, not TPU wall-times; on TPU
the same call sites run compiled) and their pure-jnp oracles (the oracle
time is the meaningful CPU number)."""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp

from repro.core.cong import CongState
from repro.core.tables import bootstrap_tables
from repro.kernels import ops, ref

Row = Tuple[str, float, str]


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6, out


def decide_bench() -> List[Row]:
    F, P = 4096, 6
    k = jax.random.key(0)
    k1, k2, k3 = jax.random.split(k, 3)
    fids = jax.random.randint(k1, (F,), 0, 1 << 30).astype(jnp.uint32)
    cp = jax.random.randint(k2, (F, P), 0, 256).astype(jnp.int32)
    cc = jax.random.randint(k3, (F, P), 0, 256).astype(jnp.int32)
    valid = jnp.ones((F, P), bool)
    us_ref, _ = _time(lambda *a: ref.lcmp_decide_ref(*a), fids, cp, cc, valid)
    us_k, _ = _time(lambda *a: ops.lcmp_decide(*a), fids, cp, cc, valid)
    return [
        ("kernel/lcmp_decide_ref_4096flows", us_ref,
         f"ns_per_decision={us_ref*1e3/F:.1f}"),
        ("kernel/lcmp_decide_pallas_interp", us_k,
         f"ns_per_decision={us_k*1e3/F:.1f}"),
    ]


def cong_bench() -> List[Row]:
    n = 1024
    tb = bootstrap_tables([100] * n)
    st = CongState.init(n)
    q = jnp.arange(n, dtype=jnp.int32) * 1000
    us_ref, _ = _time(lambda s: ref.cong_update_ref(s, q, 0, tb), st)
    us_k, _ = _time(lambda s: ops.cong_update(s, q, 0, tb), st)
    return [
        ("kernel/cong_update_ref_1024ports", us_ref,
         f"ns_per_port={us_ref*1e3/n:.1f}"),
        ("kernel/cong_update_pallas_interp", us_k,
         f"ns_per_port={us_k*1e3/n:.1f}"),
    ]


def qsr_bench() -> List[Row]:
    n = 1 << 20
    x = jax.random.normal(jax.random.key(1), (n,), jnp.float32)
    bits = jax.random.bits(jax.random.key(2), (n,), jnp.uint32)
    us_ref, _ = _time(lambda *a: ref.qsr_int8_ref(*a), x, bits)
    us_k, _ = _time(lambda *a: ops.qsr_int8(*a), x, bits)
    gbps = n * 4 / (us_ref / 1e6) / 1e9
    return [
        ("kernel/qsr_int8_ref_1M", us_ref, f"GBps={gbps:.2f}"),
        ("kernel/qsr_int8_pallas_interp_1M", us_k, "4x_compression"),
    ]


def all_benches() -> List[Row]:
    return decide_bench() + cong_bench() + qsr_bench()
