"""One benchmark per paper table/figure (LCMP, EuroSys'26).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
and writes full CSVs to benchmarks/out/. Every figure's grid runs
through ``repro.netsim.sweep``: cells sharing a trace (same scenario /
engine / cc / parameter overrides — policy, seed and workload are
dynamic axes, loads chunk on a padding budget) execute as a few compiled
XLA computations instead of a Python loop of re-traced ``run`` calls.
``us_per_call`` is therefore the group wall-clock amortized over its
cells; each figure also emits a ``<fig>/sweep`` summary row with the
total wall-clock and group count, so the CSV stream records the
sweep-engine speedup over time.

Every suite takes an ``engine`` argument (``benchmarks.run --engine``):
``"fluid"`` (default) or ``"packet"`` re-runs the same grid on the
packet-level backend — rows are tagged ``fig5[packet]/...`` and CSVs
written as ``<name>.packet.csv`` so fluid results are never clobbered.
The ``fidelity`` suite is the exception: it *always* runs both engines
and cross-validates them (the paper's testbed-vs-NS-3 §6 check, with
the packet engine standing in for NS-3 and the fluid engine under test).

Reduced-scale defaults (duration, cap_scale) keep the whole suite
CPU-tractable; pass scale="full" for paper-scale horizons. Pass
``sequential=True`` (or ``--sequential`` on benchmarks.run) to run the
pre-sweep per-cell loop — the before/after comparison baseline.
"""
from __future__ import annotations

import os
from typing import List, Tuple

import numpy as np

from repro.core.cong import CongParams
from repro.core.pathq import PathQParams
from repro.core.select import SelectParams
from repro.netsim.experiment import (ExpSpec, background_pair_ids,
                                     build_world, spec_to_cfg,
                                     traffic_pair_ids)
from repro.netsim.metrics import fct_stats, per_pair_stats, phase_stats
from repro.netsim.sweep import run_sweep
from repro.traffic.sched import build as sched_build

OUT = os.path.join(os.path.dirname(__file__), "out")
Row = Tuple[str, float, str]

_DUR = {"quick": 300_000, "default": 400_000, "full": 1_500_000}
_SIZE_EDGES = [0, 3e3, 1e4, 3e4, 1e5, 1e6, 1e7, 1e9]

# Survivorship-bias guard: slowdown percentiles are over completed flows
# only, so a policy can "win" p99 by stranding its worst flows past the
# horizon. Every CSV row carries completed/offered/completion_rate, and
# every suite emits a <fig>/low-completion row flagging cells below this
# floor — a flagged cell's percentile columns are not comparable.
COMPLETION_FLOOR = 0.99


def _comp_cols(st) -> str:
    """The per-row completion columns: ``completed,offered,crate``."""
    return f"{st.completed},{st.offered},{st.completion_rate:.4f}"


def _completion_flags(figname: str, results) -> Row:
    """One derived row per suite naming every below-floor cell. The
    comparison is written to catch NaN rates too (zero offered flows is
    the worst non-comparable cell, not a passing one)."""
    low = [(res, res.stats.completion_rate) for res in results
           if not (res.stats.completion_rate >= COMPLETION_FLOOR)]
    detail = "|".join(f"{r.spec.topology.split(':')[0]}/{r.spec.engine}/"
                      f"{r.spec.policy}@load{r.spec.load:g}"
                      f"bg{r.spec.bg_load:g}={c:.3f}" for r, c in low)
    return (f"{figname}/low-completion", 0.0,
            f"floor={COMPLETION_FLOOR};flagged={len(low)}"
            + (f";{detail}" if detail else ""))


def _csv(name: str, header: str, rows: List[str]) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name), "w") as f:
        f.write(header + "\n")
        f.writelines(r + "\n" for r in rows)


def _tag(figname: str, engine: str) -> str:
    """Row-name prefix for a suite run on a non-default engine."""
    return figname if engine == "fluid" else f"{figname}[{engine}]"


def _csvfile(name: str, engine: str) -> str:
    """CSV filename per engine (fluid keeps the historical names)."""
    return name if engine == "fluid" else name.replace(".csv", f".{engine}.csv")


def _sweep(figname: str, specs: List[ExpSpec], sequential: bool):
    """Run a figure's grid through the sweep engine; returns (results,
    per-cell us, summary row)."""
    rep = run_sweep(specs, sequential=sequential)
    total_us = rep.wall_s * 1e6
    per_cell = total_us / max(rep.num_cells, 1)
    mode = "sequential" if sequential else "batched"
    summary = (f"{figname}/sweep", total_us,
               f"mode={mode};cells={rep.num_cells};groups={rep.num_groups}")
    return rep.results, per_cell, summary


# ------------------------------------------------------------------ Figure 1
def fig1_link_utilization(scale="default", sequential=False,
                          engine="fluid") -> List[Row]:
    """[Motivation] per-link utilization under ECMP/UCMP/LCMP, 8-DC, 30%."""
    fig = _tag("fig1", engine)
    longhaul = {"DC1-DC2": 0, "DC1-DC3": 4, "DC1-DC4": 8,
                "DC1-DC5": 12, "DC1-DC6": 16, "DC1-DC7": 20}
    pols = ["ecmp", "ucmp", "lcmp"]
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol, engine=engine,
                     duration_us=_DUR[scale]) for pol in pols]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows, csv = [summary], []
    for res in results:
        u = {k: float(res.util[i]) for k, i in longhaul.items()}
        csv += [f"{res.spec.policy},{k},{v:.4f}" for k, v in u.items()]
        rows.append((f"{fig}/{res.spec.policy}", per_cell,
                     "util=" + "|".join(f"{v:.3f}" for v in u.values())))
    _csv(_csvfile("fig1_utilization.csv", engine), "policy,link,utilization",
         csv)
    return rows


# ------------------------------------------------------------------ Figure 5
def fig5_testbed_fct(scale="default", sequential=False,
                     engine="fluid") -> List[Row]:
    """Median/P99 FCT slowdown, Web Search, 8-DC testbed, 30/50/80% load.

    Each load's 5-policy row shares one trace; loads chunk by flow count."""
    fig = _tag("fig5", engine)
    specs = [ExpSpec(topology="testbed8", load=load, policy=pol,
                     engine=engine, duration_us=_DUR[scale])
             for load in [0.3, 0.5, 0.8]
             for pol in ["ecmp", "ucmp", "redte", "lcmp", "lcmp_w"]]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        csv.append(f"{s.load},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                   f"{_comp_cols(st)}")
        rows.append((f"{fig}/load{int(s.load*100)}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("fig5_testbed.csv", engine),
         "load,policy,p50,p99,completed,offered,completion_rate", csv)
    return rows


# ------------------------------------------------------------------ Figure 6
def fig6_fidelity(scale="default", sequential=False,
                  engine="fluid") -> List[Row]:
    """[Simulator stability] per-policy slowdowns must correlate across
    independent seeds (determinism + stability of the platform). The
    cross-*engine* fidelity check — the paper's actual testbed-vs-NS-3
    §6 comparison — is the separate ``fidelity`` suite."""
    fig = _tag("fig6", engine)
    cells = [(pol, load, seed)
             for pol in ["ecmp", "ucmp", "lcmp"]
             for load in [0.3, 0.5] for seed in (1, 2)]
    specs = [ExpSpec(topology="testbed8", load=load, policy=pol, seed=seed,
                     engine=engine, duration_us=_DUR["quick"])
             for pol, load, seed in cells]
    results, _, summary = _sweep(fig, specs, sequential)
    by = {cell: res.stats for cell, res in zip(cells, results)}
    xs, ys, csv = [], [], []
    for pol in ["ecmp", "ucmp", "lcmp"]:
        for load in [0.3, 0.5]:
            a, b = by[(pol, load, 1)], by[(pol, load, 2)]
            xs += [a.p50, a.p99]
            ys += [b.p50, b.p99]
            csv.append(f"{pol},{load},{a.p50:.3f},{b.p50:.3f},"
                       f"{a.p99:.3f},{b.p99:.3f},"
                       f"{a.completion_rate:.4f},{b.completion_rate:.4f}")
    r = float(np.corrcoef(np.log(xs), np.log(ys))[0, 1])
    _csv(_csvfile("fig6_fidelity.csv", engine),
         "policy,load,p50_seed1,p50_seed2,p99_seed1,p99_seed2,"
         "crate_seed1,crate_seed2", csv)
    return [summary, (f"{fig}/seed-correlation", 0.0, f"pearson_log={r:.3f}"),
            _completion_flags(fig, results)]


# -------------------------------------------------------------- Figures 7+8
def fig7_8_large_scale(scale="default", sequential=False,
                       engine="fluid") -> List[Row]:
    """13-DC all-to-all system-wide (Fig. 7) + the multi-path DC-pair case
    study (Fig. 8) extracted from the same runs."""
    fig7, fig8 = _tag("fig7", engine), _tag("fig8", engine)
    specs = [ExpSpec(topology="bso13", load=load, policy=pol, pairs="all",
                     engine=engine, duration_us=_DUR[scale],
                     cap_scale=0.0625)
             for load in [0.3, 0.5, 0.8]
             for pol in ["ecmp", "ucmp", "redte", "lcmp"]]
    results, per_cell, summary = _sweep(_tag("fig7_8", engine), specs,
                                        sequential)
    _, table = build_world("bso13")
    multi = np.nonzero(table.pair_ncand >= 3)[0]
    rows, csv7, csv8 = [summary], [], []
    for res in results:
        s, st = res.spec, res.stats
        csv7.append(f"{s.load},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                    f"{_comp_cols(st)}")
        rows.append((f"{fig7}/load{int(s.load*100)}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
        # Fig 8: restrict to pairs with multiple near-equal candidates —
        # the shared masked-stats helper, so the subset view carries its
        # OWN completion columns (the aggregate fig7 flag can't see a
        # policy stranding just the multi-path pairs' flows)
        scen, _ = build_world(s.topology)
        sub = fct_stats(res.final, table, res.flows, spec_to_cfg(s, scen),
                        mask=np.isin(res.flows.pair_id, multi))
        if sub.completed > 20:
            csv8.append(f"{s.load},{s.policy},{sub.p50:.3f},{sub.p99:.3f},"
                        f"{_comp_cols(sub)}")
            rows.append((f"{fig8}/load{int(s.load*100)}/{s.policy}", per_cell,
                         f"p50={sub.p50:.2f};p99={sub.p99:.2f};"
                         f"crate={sub.completion_rate:.4f}"))
    rows.append(_completion_flags(_tag("fig7_8", engine), results))
    _csv(_csvfile("fig7_system_wide.csv", engine),
         "load,policy,p50,p99,completed,offered,completion_rate", csv7)
    _csv(_csvfile("fig8_dcpair.csv", engine),
         "load,policy,p50,p99,completed,offered,completion_rate", csv8)
    return rows


# ------------------------------------------------------------------ Figure 9
def fig9_workloads(scale="default", sequential=False,
                   engine="fluid") -> List[Row]:
    """Workload generality: the 3-workload x 3-policy grid is one trace
    (workloads only change flow-table contents)."""
    fig = _tag("fig9", engine)
    specs = [ExpSpec(topology="testbed8", workload=wl, load=0.3, policy=pol,
                     engine=engine, duration_us=_DUR[scale])
             for wl in ["websearch", "fbhdp", "alistorage"]
             for pol in ["ecmp", "ucmp", "lcmp"]]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        csv.append(f"{s.workload},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                   f"{_comp_cols(st)}")
        rows.append((f"{fig}/{s.workload}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("fig9_workloads.csv", engine),
         "workload,policy,p50,p99,completed,offered,completion_rate", csv)
    return rows


# ----------------------------------------------------------------- Figure 10
def fig10_cc_orthogonality(scale="default", sequential=False,
                           engine="fluid") -> List[Row]:
    """CC orthogonality: cc is a static (trace-level) axis, so this grid
    compiles once per CC law and vmaps the policy axis inside each."""
    fig = _tag("fig10", engine)
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol, cc=cc,
                     engine=engine, duration_us=_DUR[scale])
             for cc in ["dcqcn", "hpcc", "timely", "dctcp"]
             for pol in ["ecmp", "ucmp", "lcmp"]]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        csv.append(f"{s.cc},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                   f"{_comp_cols(st)}")
        rows.append((f"{fig}/{s.cc}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("fig10_cc.csv", engine),
         "cc,policy,p50,p99,completed,offered,completion_rate", csv)
    return rows


# ----------------------------------------------------------------- Figure 11
def fig11_ablations(scale="default", sequential=False,
                    engine="fluid") -> List[Row]:
    """(a) rm-alpha/rm-beta; (b) global (alpha,beta); (c) (w_dl,w_lc);
    (d) (w_ql,w_tl,w_dp) — per-size-bucket p50/p99 on the testbed @30%.

    Parameter dataclasses are static (baked into the trace), so each
    variant is its own sweep group — the engine handles the degenerate
    1-cell-per-group grid transparently."""
    fig = _tag("fig11", engine)
    variants = {
        # (a) component ablation
        "full": {},
        "rm-alpha": dict(select=SelectParams(alpha=0, beta=1)),
        "rm-beta": dict(select=SelectParams(alpha=3, beta=0)),
        # (b) global fusion weights
        "ab-1-1": dict(select=SelectParams(alpha=1, beta=1)),
        "ab-1-3": dict(select=SelectParams(alpha=1, beta=3)),
        # (c) path-quality weights
        "dl-1-1": dict(pathq=PathQParams(w_dl=1, w_lc=1)),
        "dl-1-3": dict(pathq=PathQParams(w_dl=1, w_lc=3)),
        # (d) congestion weights
        "cg-1-2-1": dict(congp=CongParams(w_ql=1, w_tl=2, w_dp=1)),
        "cg-1-1-2": dict(congp=CongParams(w_ql=1, w_tl=1, w_dp=2)),
    }
    specs = [ExpSpec(topology="testbed8", load=0.3, policy="lcmp",
                     engine=engine, duration_us=_DUR[scale], **over)
             for over in variants.values()]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows, csv = [summary], []
    for name, res in zip(variants, results):
        st = res.stats
        # completion is a whole-run property (by_size_bucket only sees
        # completed flows) — the run_* prefix keeps the bucket-keyed rows
        # from reading as per-bucket counts
        for b, v in st.by_size_bucket(_SIZE_EDGES).items():
            csv.append(f"{name},{b},{v['p50']:.3f},{v['p99']:.3f},{v['n']},"
                       f"{_comp_cols(st)}")
        rows.append((f"{fig}/{name}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("fig11_ablations.csv", engine),
         "variant,size_bucket,p50,p99,n,"
         "run_completed,run_offered,run_completion_rate",
         csv)
    return rows


# --------------------------------------------------- failover (claim §3.4)
def failover_bench(scale="default", sequential=False,
                   engine="fluid") -> List[Row]:
    """Data-plane fast-failover: completion rate + tail with the 100G/5ms
    long-haul link killed a third into the run (lazy re-hash, zero
    control-plane involvement). Runs via the ``testbed8_failover``
    scenario — both policies share the schedule, so the pair is one
    sweep group."""
    fig = _tag("failover", engine)
    fail_ms = _DUR[scale] // 3000
    specs = [ExpSpec(topology=f"testbed8_failover:fail_ms={fail_ms}",
                     load=0.3, policy=pol, engine=engine,
                     duration_us=_DUR[scale])
             for pol in ["lcmp", "ecmp"]]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows = [summary]
    for res in results:
        st = res.stats
        rows.append((f"{fig}/{res.spec.policy}", per_cell,
                     f"completed={st.completed}/{st.offered};"
                     f"crate={st.completion_rate:.4f};p99={st.p99:.2f}"))
    rows.append(_completion_flags(fig, results))
    return rows


# ------------------------------------------- staleness ablation (§7.3, new)
def staleness_ablation(scale="default", sequential=False,
                       engine="fluid") -> List[Row]:
    """[§7.3] Signal-staleness grid on the ``staleness`` scenario (a
    *remote* span of the good route silently degrades): sig_delay_scale
    x ctrl_period_us, with the policy axis dynamic inside each trace.
    LCMP's tail worsens as the routed signal ages (saturating once it is
    staler than the queue-buildup timescale; lcmp_w's capacity-weighted
    hash is noisier at reduced scale); oblivious ecmp is exactly flat.
    Each CSV row also
    records the degraded route's *installed* C_path at horizon end; the
    ctrl_period_us=0 rows keep the build-time score while every live
    period shows the repriced one — the control-plane refresh
    demonstrably repricing the route, visible in the CSV itself."""
    fig = _tag("staleness", engine)
    # degrade early (1/5 of the run): the tail must be dominated by flows
    # that lived through the stale-signal window, not by generic load
    deg_ms = max(_DUR[scale] // 5000, 50)
    top = f"staleness:deg_ms={deg_ms}"
    # operating point: 40% load keeps the tail out of horizon saturation
    # (at 50% the p99 is dominated by horizon-bound stragglers and the
    # staleness columns go flat — see tests/test_signal_plane.py, which
    # asserts the hurt at this point); the ladder spans to x6 because
    # the per-hop backward delay on the degraded span is 25 ms and the
    # queue-buildup timescale eats the x1 point
    grid = [(sds, per) for sds in (0.0, 2.0, 6.0)
            for per in (0, 50_000, 200_000)]
    specs = [ExpSpec(topology=top, load=0.4, policy=pol, engine=engine,
                     duration_us=_DUR[scale], seed=1,
                     sig_delay_scale=sds, ctrl_period_us=per)
             for sds, per in grid
             for pol in ["ecmp", "lcmp", "lcmp_w"]]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    scen, table = build_world(top)
    deg_link = scen.degrade_sched[0][0]
    deg_path = int(np.nonzero(
        (np.asarray(table.path_links) == deg_link).any(-1))[0][0])
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        cp = int(res.final.c_path[deg_path])
        csv.append(f"{s.sig_delay_scale:g},{s.ctrl_period_us},{s.policy},"
                   f"{st.p50:.3f},{st.p99:.3f},{cp},{_comp_cols(st)}")
        rows.append((f"{fig}/sds{s.sig_delay_scale:g}"
                     f"/cp{s.ctrl_period_us // 1000}ms/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f};cpath_deg={cp}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("staleness_ablation.csv", engine),
         "sig_delay_scale,ctrl_period_us,policy,p50,p99,cpath_degraded,"
         "completed,offered,completion_rate", csv)
    return rows


# ------------------------------------------------- scenario showcase (new)
def scenarios_bench(scale="default", sequential=False,
                    engine="fluid") -> List[Row]:
    """Beyond-paper scenario regimes from the registry: a segmented
    long-haul mesh (MatchRDMA-style), silent capacity degradation on the
    13-DC backbone, and delay-asymmetry jitter on the testbed."""
    fig = _tag("scenarios", engine)
    specs = [ExpSpec(topology=top, load=0.3, policy=pol, engine=engine,
                     duration_us=_DUR[scale], pairs=pairs,
                     cap_scale=cap_scale)
             for top, pairs, cap_scale in [
                 ("longhaul_mesh:routes=6,segs=3", "main", 0.125),
                 (f"bso13_degrade:at_ms={_DUR[scale] // 3000}", "all", 0.0625),
                 ("jitter:base=testbed8,frac=0.3", "main", 0.125),
             ]
             for pol in ["lcmp", "ecmp"]]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        name = s.topology.split(":")[0]
        csv.append(f"{name},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                   f"{_comp_cols(st)}")
        rows.append((f"{fig}/{name}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f};"
                     f"completed={st.completed}/{st.offered}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("scenarios.csv", engine),
         "scenario,policy,p50,p99,completed,offered,completion_rate", csv)
    return rows


# ------------------------------ large-scale 2000 km WAN (headline claim)
def fig_large(scale="default", sequential=False, engine="fluid") -> List[Row]:
    """[Headline scale] Multi-pair 2000 km WAN: the paper's "large-scale
    simulations under the 2000 km inter-DC scenario", on the ``wan2000``
    generator (24 heterogeneous DCs, segmented OTN hauls, 42 advertised
    multi-path pairs). The foreground DC0->DC1 pair (fast-fat / medium /
    slow-thin parallel hauls) is measured under background cross-traffic
    dosed independently on every other advertised pair (``bg_load``),
    LCMP vs every baseline, with the fattest main-pair haul's first OTN
    span silently degraded to a quarter capacity a third into the run —
    the regime where oblivious and statically-weighted placement keeps
    dosing a crippled haul and only congestion-aware placement routes
    around it. Each CSV row carries the foreground AND background
    percentiles, aggregate AND worst-per-pair completion (survivorship
    guards — a policy must not win by stranding one pair's flows), and
    the realized-vs-target offered-load error (dosing accuracy); derived
    rows report the paper-consistent ordering check — LCMP p50/p99 at or
    below every baseline — per background level. The pinned quick-scale
    configuration (the CI operating point) passes the check at both
    levels; at longer horizons RedTE's 100 ms re-optimization loop can
    close the *median* gap (its reweighting eventually also avoids the
    degraded haul) while LCMP keeps the tail win — the rows make that
    visible instead of hiding it."""
    fig = _tag("fig_large", engine)
    deg_ms = _DUR[scale] // 3000
    top = f"wan2000:dcs=24,segs=2,chords=12,deg_ms={deg_ms},deg_factor=0.25"
    pols = ["ecmp", "ucmp", "wcmp", "redte", "lcmp"]
    bgs = [0.15, 0.3]
    # seed pinned where realized offered load lands within 5% of target
    # at every scale (heavy-tailed sizes make the realized byte-rate
    # noisy; the dose_err column proves the accuracy row by row)
    specs = [ExpSpec(topology=top, load=0.5, bg_load=bg, policy=pol,
                     engine=engine, duration_us=_DUR[scale], seed=9,
                     pairs="main", cap_scale=0.0625)
             for bg in bgs for pol in pols]
    results, per_cell, summary = _sweep(fig, specs, sequential)
    scen, table = build_world(top)
    cfg = spec_to_cfg(specs[0], scen)
    rows, csv, by = [summary], [], {}
    for res in results:
        s, st, fg, bg = res.spec, res.stats, res.stats_fg, res.stats_bg
        by[(s.bg_load, s.policy)] = fg
        derr = res.flows.dosing_error()
        # per-pair survivorship: the worst single pair's completion rate
        # (aggregate completion can hide one fully-starved pair)
        per_pair = per_pair_stats(res.final, table, res.flows, cfg)
        min_crate = min(p.completion_rate for p in per_pair.values())
        csv.append(f"{s.bg_load:g},{s.policy},{fg.p50:.3f},{fg.p99:.3f},"
                   f"{bg.p50:.3f},{bg.p99:.3f},{_comp_cols(st)},"
                   f"{min_crate:.4f},{derr:.4f}")
        rows.append((f"{fig}/bg{int(s.bg_load*100)}/{s.policy}", per_cell,
                     f"fg_p50={fg.p50:.2f};fg_p99={fg.p99:.2f};"
                     f"bg_p99={bg.p99:.2f};crate={st.completion_rate:.4f};"
                     f"min_pair_crate={min_crate:.4f};dose_err={derr:.4f}"))
    for bg in bgs:
        base = [p for p in pols if p != "lcmp"]
        ok = all(by[(bg, "lcmp")].p50 <= by[(bg, p)].p50
                 and by[(bg, "lcmp")].p99 <= by[(bg, p)].p99 for p in base)
        rows.append((f"{fig}/ordering/bg{int(bg*100)}", 0.0,
                     f"lcmp_beats_all={ok}"))
    rows.append(_completion_flags(fig, results))
    _csv(_csvfile("fig_large_wan2000.csv", engine),
         "bg_load,policy,fg_p50,fg_p99,bg_p50,bg_p99,"
         "completed,offered,completion_rate,min_pair_crate,dose_err", csv)
    return rows


# ------------------------------- mid-flow re-decision baselines (§7 SOTA)
def fig_multipath(scale="default", sequential=False,
                  engine="both") -> List[Row]:
    """[§7 SOTA comparison] LCMP vs the mid-flow re-decision baselines —
    FatPaths (layered candidate sets + flowlet re-hash), AMP-style
    per-subflow ECMP (4 subflows, parent scored at the last subflow),
    and the lcmp_r periodic-re-decision ablation — on two grids:

    - the 8-DC ``staleness`` testbed (remote-span silent degrade, stale
      signal plane at x2 delay) on BOTH engines: the fluid backend
      drives the timer-epoch re-decision path, the packet backend the
      flowlet idle-gap detector, so the CSV records each eligibility
      mechanism under its native engine (this suite ignores --engine);
    - the 2000 km ``wan2000`` mesh (degraded fattest haul + background
      cross-traffic) on the fluid engine — the paper-scale ordering
      check: congestion-aware LCMP must hold its tail at or below the
      congestion-oblivious re-decision baselines (derived rows assert
      LCMP fg-p99 <= FatPaths/AMP fg-p99 with per-row completion).

    Re-decision knobs are static sweep axes, so armed cells trace their
    own groups and every unarmed cell keeps the pinned-path program."""
    del engine
    fig = "fig_multipath"
    gap_us, period_us = 1000, 10_000
    deg_ms = max(_DUR[scale] // 5000, 50)

    def spec(pol, eng, **kw):
        knobs = {}
        if pol in ("fatpaths", "lcmp_r"):
            # both knobs armed; wants_redecide picks the engine's one
            knobs = dict(flowlet_gap_us=gap_us,
                         redecide_period_us=period_us)
        if pol == "amp":
            knobs["n_subflows"] = 4
        return ExpSpec(policy=pol, engine=eng, duration_us=_DUR[scale],
                       **knobs, **kw)

    tb_top = f"staleness:deg_ms={deg_ms}"
    tb_pols = ["ecmp", "fatpaths", "amp", "lcmp", "lcmp_r"]
    tb = [spec(pol, eng, topology=tb_top, load=0.4, seed=1,
               sig_delay_scale=2.0)
          for eng in ("fluid", "packet") for pol in tb_pols]
    wan_top = (f"wan2000:dcs=24,segs=2,chords=12,"
               f"deg_ms={_DUR[scale] // 3000},deg_factor=0.25")
    wan_pols = ["ecmp", "fatpaths", "amp", "lcmp"]
    wan = [spec(pol, "fluid", topology=wan_top, load=0.5, bg_load=0.15,
                seed=9, pairs="main", cap_scale=0.0625)
           for pol in wan_pols]
    results, per_cell, summary = _sweep(fig, tb + wan, sequential)
    rows, csv, wan_by = [summary], [], {}
    for res in results:
        s, st, fg = res.spec, res.stats, res.stats_fg
        part = "wan2000" if s.topology.startswith("wan2000") else "testbed8"
        if part == "wan2000":
            wan_by[s.policy] = (fg, st)
        csv.append(f"{part},{s.engine},{s.policy},{fg.p50:.3f},{fg.p99:.3f},"
                   f"{_comp_cols(st)}")
        rows.append((f"{fig}/{part}/{s.engine}/{s.policy}", per_cell,
                     f"p50={fg.p50:.2f};p99={fg.p99:.2f};"
                     f"crate={st.completion_rate:.4f}"))
    # the acceptance ordering: LCMP's tail at or below each re-decision
    # baseline on the degraded WAN grid, every compared row above floor
    lc = wan_by["lcmp"]
    for base in ("fatpaths", "amp"):
        b = wan_by[base]
        comparable = (lc[1].completion_rate >= COMPLETION_FLOOR
                      and b[1].completion_rate >= COMPLETION_FLOOR)
        rows.append((f"{fig}/ordering/lcmp-vs-{base}", 0.0,
                     f"lcmp_p99={lc[0].p99:.2f};{base}_p99={b[0].p99:.2f};"
                     f"holds={comparable and lc[0].p99 <= b[0].p99}"))
    rows.append(_completion_flags(fig, results))
    _csv("fig_multipath.csv",
         "grid,engine,policy,p50,p99,completed,offered,completion_rate",
         csv)
    return rows


# ------------------------------- geo-grounded diurnal WAN (ROADMAP item 1)
def fig_geo(scale="default", sequential=False, engine="both") -> List[Row]:
    """[Geo diurnal] Planetary 20-DC WAN over one compressed 24 h cycle:
    the ``geo`` scenario places real DC metros at their lat/lon (haul
    delays = geodesic distance at ~0.67c, chained from 2000 km-class OTN
    spans) and every advertised pair's offered load follows a diurnal
    sinusoid phase-shifted by its source DC's timezone (longitude/15 deg)
    and weighted by metro population, with one global flash crowd
    mid-cycle (``ExpSpec.load_sched``). The population-heaviest ring
    edge (fast-fat/slow-thin parallel hauls) is measured under that
    breathing cross-traffic while its fattest haul's first span is
    silently degraded to a tenth of capacity right at dawn — before the
    first off-peak trough ends, so static nominal-capacity weighting is
    wrong for the whole day, the regime the paper's cost repricing
    targets — LCMP vs oblivious (ECMP), statically-weighted (WCMP) and
    flowlet re-hash (FatPaths) baselines plus the lcmp_r re-decision
    *ablation*, on BOTH engines (this suite ignores --engine). Rows
    report slowdown percentiles **per diurnal phase** — peak / off-peak
    / crossover segments of the measured pair's own schedule row —
    because tracking the cycle, not winning one steady state, is the
    figure of merit; derived ``fig_geo/ordering/<engine>/<phase>`` rows
    assert LCMP p50/p99 at or below every baseline per phase with LCMP
    completion above the floor (baselines below the floor report
    survivor-biased percentiles — flattering to them — so they are
    still compared; their completion rates ship in the CSV and the
    survivorship flags), and ``fig_geo/ablation/<engine>/redecide``
    reports what free periodic re-decision adds on top of LCMP."""
    del engine
    fig = "fig_geo"
    dur = _DUR[scale]
    # amp 0.45 keeps the trough hot enough that WCMP's 59% nominal-cap
    # share of the degraded haul queues even off-peak, while the peak
    # (2.6x the trough) still completes for LCMP
    amp = 0.45
    deg_ms = max(dur // 30_000, 10)
    # flash lands INSIDE the evening peak (62% of the cycle for
    # peak_h=20): bursting a crossover segment instead pushes baseline
    # completion below the floor without testing peak tracking
    flash_at_ms, flash_dur_ms = int(dur * 0.62) // 1000, max(dur // 10_000, 10)
    top = f"geo:dcs=20,chords=10,deg_ms={deg_ms},deg_factor=0.1"
    sched = (f"diurnal:amp={amp},segs=24,flash_at_ms={flash_at_ms},"
             f"flash_dur_ms={flash_dur_ms},flash_mult=2")
    pols = ["ecmp", "wcmp", "fatpaths", "lcmp_r", "lcmp"]

    def spec(pol, eng):
        knobs = {}
        if pol in ("fatpaths", "lcmp_r"):
            # both re-decision knobs armed; wants_redecide picks the
            # engine-native one (fluid: timer epoch, packet: flowlet
            # gap). The fluid epoch is the RedTE-style 100 ms control
            # timescale — not faster: fluid re-decision pays no
            # reordering cost, so a short epoch is a free oracle no
            # hardware flowlet scheme gets
            knobs = dict(flowlet_gap_us=1000,
                         redecide_period_us=100_000)
        return ExpSpec(topology=top, policy=pol, engine=eng, load=0.2,
                       bg_load=0.1, duration_us=dur, seed=6, pairs="main",
                       cap_scale=0.0625, load_sched=sched, **knobs)

    specs = [spec(pol, eng) for eng in ("fluid", "packet") for pol in pols]
    results, per_cell, summary = _sweep(fig, specs, sequential)

    # phase labels come from the measured pair's OWN schedule row (the
    # same arrays make_flows dosed with): peak >= 1 + amp/2 (the flash
    # window lands here too), off-peak <= 1 - amp/2, crossover between
    scen, table = build_world(top)
    cfg = spec_to_cfg(specs[0], scen)
    fg_ids = traffic_pair_ids(specs[0], scen, table)
    sched_t, fg_rows, _ = sched_build(
        sched, dur, table, scen, fg_ids,
        background_pair_ids(table, fg_ids))
    labels = ["peak" if v >= 1 + amp / 2 else
              "offpeak" if v <= 1 - amp / 2 else "crossover"
              for v in fg_rows[0]]
    phases = list(dict.fromkeys(labels))

    rows, csv, by = [summary], [], {}
    for res in results:
        s, st = res.spec, res.stats
        derr = res.flows.dosing_error()
        ph = phase_stats(res.final, table, res.flows, cfg, sched_t,
                         labels, mask=res.flows.foreground)
        for name, p in ph.items():
            by[(s.engine, s.policy, name)] = p
            csv.append(f"{s.engine},{s.policy},{name},{p.p50:.3f},"
                       f"{p.p99:.3f},{_comp_cols(p)},{derr:.4f}")
        rows.append((f"{fig}/{s.engine}/{s.policy}", per_cell,
                     ";".join(f"{n}_p99={p.p99:.2f}"
                              for n, p in ph.items())
                     + f";crate={st.completion_rate:.4f}"
                     + f";dose_err={derr:.4f}"))
    # lcmp_r is an *ablation* of LCMP (same law + periodic re-decision;
    # the fluid engine charges nothing for the re-hash, so it is LCMP
    # made strictly stronger), not an external baseline — same split
    # fig_multipath draws. Ordering gates on the true baselines; the
    # re-decision delta gets its own ablation row per engine.
    base = [p for p in pols if p not in ("lcmp", "lcmp_r")]
    for eng in ("fluid", "packet"):
        for name in phases:
            lc = by[(eng, "lcmp", name)]
            # the floor applies to LCMP only: a baseline that strands
            # flows on the degraded haul reports survivor-biased
            # percentiles, which can only flatter the baseline — beating
            # them anyway is the conservative comparison, and voiding
            # the row would let the baseline's failure erase LCMP's win.
            # Baseline completion stays visible in the CSV and the
            # per-suite survivorship flags.
            ok = (lc.completion_rate >= COMPLETION_FLOOR) and all(
                lc.p50 <= by[(eng, p, name)].p50
                and lc.p99 <= by[(eng, p, name)].p99 for p in base)
            rows.append((f"{fig}/ordering/{eng}/{name}", 0.0,
                         f"lcmp_p50={lc.p50:.2f};lcmp_p99={lc.p99:.2f};"
                         f"holds={ok}"))
        rows.append((f"{fig}/ablation/{eng}/redecide", 0.0,
                     ";".join(f"{n}_dp99={by[(eng, 'lcmp_r', n)].p99 - by[(eng, 'lcmp', n)].p99:+.2f}"
                              for n in phases)))
    rows.append(_completion_flags(fig, results))
    _csv("fig_geo.csv",
         "engine,policy,phase,p50,p99,completed,offered,"
         "completion_rate,dose_err", csv)
    return rows


# ----------------------- training co-simulation (repro.cosim, closing loop)
def fig_training(scale="default", sequential=False,
                 engine="both") -> List[Row]:
    """[Training cosim] The training job IS the workload: ``repro.cosim``
    lowers a ``configs/`` smoke architecture + a ``launch/shapes`` train
    cell through ``dist.lcmp_collectives``' exact bucket accounting into
    periodic reduce-scatter / all-gather bursts on the measured wan2000
    pair, layered over Poisson cross-traffic (``bg_load``), and scores
    each policy by *iteration time* under barrier semantics — the
    optimizer waits on the straggler bucket, so one slow route taxes the
    whole step. Grid: model x bg_load x degraded-haul (the fattest
    haul's first OTN span silently drops to a tenth of capacity a third
    of the way through training) x {ECMP, WCMP, FatPaths, MatchRDMA,
    LCMP} on BOTH engines (this suite ignores --engine). Percentiles
    are ``pct_strict`` — an iteration that never completes counts as
    +inf, not excluded, so stranding a step can only hurt. Ordering
    rows ``fig_training/ordering/<engine>/<model>`` assert LCMP
    iteration p50/p99 at or below every baseline at the loaded design
    point (bg=0.15, degraded) with LCMP flow completion above the
    floor; the light-load and healthy-haul arms ship in the CSV as
    contrast — there the policies converge (no queueing to dodge),
    which is the honest boundary of the claim. MatchRDMA (segmented
    per-span rate matching) reads the same delayed congestion plane
    LCMP does; its winner-take-all matched-rate argmax herds onto one
    haul a telemetry RTT late under pressure, which is exactly where
    the ``fig_training/degradation`` rows show its tail blow up."""
    del engine
    from repro.cosim import build_plan, iteration_stats
    fig = "fig_training"
    dur = _DUR[scale]
    deg_ms = dur // 3000
    base_top = "wan2000:dcs=8,segs=2,chords=4"
    deg_top = f"{base_top},deg_ms={deg_ms},deg_factor=0.1"
    models = ("qwen3-4b", "gemma2-9b")
    bgs = (0.1, 0.15)
    design_bg = 0.15
    pols = ("ecmp", "wcmp", "fatpaths", "matchrdma", "lcmp")

    specs = [ExpSpec(topology=top, policy=pol, engine=eng, load=0.7,
                     bg_load=bg, duration_us=dur, seed=9, pairs="main",
                     cap_scale=0.0625, cosim_model=m, cosim_iters=6)
             for eng in ("fluid", "packet")
             for top in (base_top, deg_top)
             for m in models for bg in bgs for pol in pols]
    results, per_cell, summary = _sweep(fig, specs, sequential)

    rows, csv, by, plans = [summary], [], {}, {}
    for res in results:
        s = res.spec
        key = (s.topology, s.cosim_model)
        if key not in plans:
            scen, table = build_world(s.topology)
            plans[key] = build_plan(s, scen, table)
        it = iteration_stats(plans[key], res.flows, res.final)
        deg = int(s.topology == deg_top)
        by[(s.engine, deg, s.cosim_model, s.bg_load, s.policy)] = (
            it, res.stats)
        csv.append(f"{s.engine},{s.cosim_model},{s.bg_load:g},{deg},"
                   f"{s.policy},{it.pct_strict(50):.3f},"
                   f"{it.pct_strict(99):.3f},{it.iters_done},"
                   f"{it.iters_total},{_comp_cols(res.stats)}")
        if deg and s.bg_load == design_bg:
            rows.append((f"{fig}/{s.engine}/{s.cosim_model}/{s.policy}",
                         per_cell,
                         f"iter_p50={it.pct_strict(50):.2f}ms;"
                         f"iter_p99={it.pct_strict(99):.2f}ms;"
                         f"iters={it.iters_done}/{it.iters_total};"
                         f"crate={res.stats.completion_rate:.4f}"))
    # acceptance ordering at the design point: LCMP iteration p50/p99 at
    # or below EVERY baseline (matchrdma included) per engine x model.
    # The completion floor applies to LCMP only — pct_strict already
    # charges a baseline's stranded iterations as +inf, so comparing
    # against an under-completing baseline is conservative (fig_geo's
    # argument, one level up the stack).
    for eng in ("fluid", "packet"):
        for m in models:
            lc, lc_st = by[(eng, 1, m, design_bg, "lcmp")]
            ok = (lc_st.completion_rate >= COMPLETION_FLOOR) and all(
                lc.pct_strict(50) <= by[(eng, 1, m, design_bg, p)][0].pct_strict(50)
                and lc.pct_strict(99) <= by[(eng, 1, m, design_bg, p)][0].pct_strict(99)
                for p in pols if p != "lcmp")
            rows.append((f"{fig}/ordering/{eng}/{m}", 0.0,
                         f"lcmp_p50={lc.pct_strict(50):.2f};"
                         f"lcmp_p99={lc.pct_strict(99):.2f};holds={ok}"))
        # what the mid-run degradation costs each policy's tail: healthy
        # vs degraded iteration p99 at the design load (first model)
        rows.append((f"{fig}/degradation/{eng}", 0.0,
                     ";".join(
                         f"{p}_dp99={by[(eng, 1, models[0], design_bg, p)][0].pct_strict(99) - by[(eng, 0, models[0], design_bg, p)][0].pct_strict(99):+.2f}"
                         for p in pols)))
    rows.append(_completion_flags(fig, results))
    _csv("fig_training.csv",
         "engine,model,bg_load,degraded,policy,iter_p50_ms,iter_p99_ms,"
         "iters_done,iters_total,completed,offered,completion_rate", csv)
    return rows


# -------------------------------------- cross-engine fidelity (§6, new)
def fidelity_bench(scale="default", sequential=False,
                   engine="both") -> List[Row]:
    """[§6 fidelity] Fluid-vs-packet cross-validation — the reproduction
    analogue of the paper's testbed-vs-NS-3 correlation (r >= 0.95): the
    same scenario x policy grid runs on BOTH engines (the ``engine``
    argument is ignored; this suite is inherently dual) and the CSV
    records per-policy p50/p99 slowdown for each backend plus the
    deltas. Derived rows report the cross-engine log-space Pearson
    correlation over all (cell, percentile) points and whether the
    paper's headline ordering — LCMP below ECMP — holds under both
    backends on the clean testbed. Grids: the 8-DC testbed at 30% (the
    Fig. 5 operating point) and the remote-span ``staleness`` degrade at
    40% (the regime where the engines' queue models differ most: the
    fluid engine estimates queue waits analytically, the packet engine
    makes flows *experience* them)."""
    del engine
    deg_ms = max(_DUR[scale] // 5000, 50)
    cells = [("testbed8", 0.3), (f"staleness:deg_ms={deg_ms}", 0.4)]
    pols = ["ecmp", "ucmp", "lcmp"]
    specs = [ExpSpec(topology=top, load=load, policy=pol, engine=eng,
                     duration_us=_DUR[scale], seed=1)
             for top, load in cells for pol in pols
             for eng in ("fluid", "packet")]
    results, per_cell, summary = _sweep("fidelity", specs, sequential)
    by = {(r.spec.topology, r.spec.policy, r.spec.engine): r.stats
          for r in results}
    rows, csv = [summary], []
    fl, pk = [], []
    for top, load in cells:
        name = top.split(":")[0]
        for pol in pols:
            a, b = by[(top, pol, "fluid")], by[(top, pol, "packet")]
            fl += [a.p50, a.p99]
            pk += [b.p50, b.p99]
            csv.append(f"{name},{pol},{a.p50:.3f},{a.p99:.3f},"
                       f"{b.p50:.3f},{b.p99:.3f},"
                       f"{b.p50 - a.p50:.3f},{b.p99 - a.p99:.3f},"
                       f"{a.completion_rate:.4f},{b.completion_rate:.4f}")
            rows.append((f"fidelity/{name}/{pol}", per_cell,
                         f"fluid_p50={a.p50:.2f};packet_p50={b.p50:.2f};"
                         f"fluid_p99={a.p99:.2f};packet_p99={b.p99:.2f}"))
    r = float(np.corrcoef(np.log(fl), np.log(pk))[0, 1])
    rows.append(("fidelity/engine-correlation", 0.0, f"pearson_log={r:.3f}"))
    t8 = {(pol, eng): by[("testbed8", pol, eng)] for pol in pols
          for eng in ("fluid", "packet")}
    order_ok = all(t8[("lcmp", eng)].p50 < t8[("ecmp", eng)].p50
                   and t8[("lcmp", eng)].p99 < t8[("ecmp", eng)].p99
                   for eng in ("fluid", "packet"))
    rows.append(("fidelity/lcmp-beats-ecmp-both-engines", 0.0,
                 f"holds={order_ok}"))
    rows.append(_completion_flags("fidelity", results))
    _csv("fidelity.csv",
         "scenario,policy,p50_fluid,p99_fluid,p50_packet,p99_packet,"
         "dp50,dp99,crate_fluid,crate_packet", csv)
    return rows
