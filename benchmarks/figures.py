"""One benchmark per paper table/figure (LCMP, EuroSys'26).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
where ``us_per_call`` is the wall-clock of the underlying sim run and
``derived`` packs the figure's key numbers. Full CSVs are also written to
benchmarks/out/.

Reduced-scale defaults (duration, cap_scale) keep the whole suite
CPU-tractable; pass scale="full" for paper-scale horizons.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import List, Tuple

import numpy as np

from repro.core.cong import CongParams
from repro.core.pathq import PathQParams
from repro.core.select import SelectParams
from repro.netsim.experiment import ExpSpec, build_experiment, run_experiment
from repro.netsim import fluid, metrics

OUT = os.path.join(os.path.dirname(__file__), "out")
Row = Tuple[str, float, str]

_DUR = {"quick": 300_000, "default": 400_000, "full": 1_500_000}
_SIZE_EDGES = [0, 3e3, 1e4, 3e4, 1e5, 1e6, 1e7, 1e9]


def _csv(name: str, header: str, rows: List[str]) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name), "w") as f:
        f.write(header + "\n")
        f.writelines(r + "\n" for r in rows)


def _run(spec: ExpSpec):
    t0 = time.perf_counter()
    stats, util, extra = run_experiment(spec)
    return stats, util, extra, (time.perf_counter() - t0) * 1e6


# ------------------------------------------------------------------ Figure 1
def fig1_link_utilization(scale="default") -> List[Row]:
    """[Motivation] per-link utilization under ECMP/UCMP/LCMP, 8-DC, 30%."""
    rows, csv = [], []
    longhaul = {"DC1-DC2": 0, "DC1-DC3": 4, "DC1-DC4": 8,
                "DC1-DC5": 12, "DC1-DC6": 16, "DC1-DC7": 20}
    for pol in ["ecmp", "ucmp", "lcmp"]:
        spec = ExpSpec(topology="testbed8", load=0.3, policy=pol,
                       duration_us=_DUR[scale])
        stats, util, _, us = _run(spec)
        u = {k: float(util[i]) for k, i in longhaul.items()}
        csv += [f"{pol},{k},{v:.4f}" for k, v in u.items()]
        rows.append((f"fig1/{pol}", us,
                     "util=" + "|".join(f"{v:.3f}" for v in u.values())))
    _csv("fig1_utilization.csv", "policy,link,utilization", csv)
    return rows


# ------------------------------------------------------------------ Figure 5
def fig5_testbed_fct(scale="default") -> List[Row]:
    """Median/P99 FCT slowdown, Web Search, 8-DC testbed, 30/50/80% load."""
    rows, csv = [], []
    for load in [0.3, 0.5, 0.8]:
        for pol in ["ecmp", "ucmp", "redte", "lcmp", "lcmp_w"]:
            spec = ExpSpec(topology="testbed8", load=load, policy=pol,
                           duration_us=_DUR[scale])
            stats, _, _, us = _run(spec)
            csv.append(f"{load},{pol},{stats.p50:.3f},{stats.p99:.3f},"
                       f"{stats.completed}")
            rows.append((f"fig5/load{int(load*100)}/{pol}", us,
                         f"p50={stats.p50:.2f};p99={stats.p99:.2f}"))
    _csv("fig5_testbed.csv", "load,policy,p50,p99,completed", csv)
    return rows


# ------------------------------------------------------------------ Figure 6
def fig6_fidelity(scale="default") -> List[Row]:
    """[Simulator fidelity] The paper correlates testbed vs NS-3 (r>=0.95).
    Without hardware we check the analogous internal-consistency property:
    per-policy slowdowns correlate across independent seeds (determinism +
    stability of the simulation platform)."""
    rows, csv = [], []
    xs, ys = [], []
    for pol in ["ecmp", "ucmp", "lcmp"]:
        for load in [0.3, 0.5]:
            a = _run(dataclasses.replace(
                ExpSpec(topology="testbed8", load=load, policy=pol,
                        duration_us=_DUR["quick"]), seed=1))[0]
            b = _run(dataclasses.replace(
                ExpSpec(topology="testbed8", load=load, policy=pol,
                        duration_us=_DUR["quick"]), seed=2))[0]
            xs += [a.p50, a.p99]
            ys += [b.p50, b.p99]
            csv.append(f"{pol},{load},{a.p50:.3f},{b.p50:.3f},{a.p99:.3f},{b.p99:.3f}")
    r = float(np.corrcoef(np.log(xs), np.log(ys))[0, 1])
    _csv("fig6_fidelity.csv", "policy,load,p50_seed1,p50_seed2,p99_seed1,p99_seed2", csv)
    return [("fig6/seed-correlation", 0.0, f"pearson_log={r:.3f}")]


# -------------------------------------------------------------- Figures 7+8
def fig7_8_large_scale(scale="default") -> List[Row]:
    """13-DC all-to-all system-wide (Fig. 7) + the multi-path DC-pair case
    study (Fig. 8) extracted from the same runs."""
    rows, csv7, csv8 = [], [], []
    for load in [0.3, 0.5, 0.8]:
        for pol in ["ecmp", "ucmp", "redte", "lcmp"]:
            spec = ExpSpec(topology="bso13", load=load, policy=pol,
                           pairs="all", duration_us=_DUR[scale],
                           cap_scale=0.0625)
            stats, _, (t, table, flows, cfg, final), us = _run(spec)
            csv7.append(f"{load},{pol},{stats.p50:.3f},{stats.p99:.3f}")
            rows.append((f"fig7/load{int(load*100)}/{pol}", us,
                         f"p50={stats.p50:.2f};p99={stats.p99:.2f}"))
            # Fig 8: restrict to a pair with multiple near-equal candidates
            pidx = table.pair_index()
            import numpy as _np
            multi = _np.nonzero(table.pair_ncand >= 3)[0]
            sel = _np.isin(flows.pair_id, multi)
            done = _np.asarray(final.done) & sel
            if done.sum() > 20:
                prop = table.pair_ideal_prop[flows.pair_id].astype(float)
                cap = table.pair_ideal_cap[flows.pair_id] * 125.0 * cfg.cap_scale
                ideal = prop + flows.size_bytes / cap
                sl = _np.maximum(_np.asarray(final.fct_us)[done] / ideal[done], 1)
                p50, p99 = _np.percentile(sl, 50), _np.percentile(sl, 99)
                csv8.append(f"{load},{pol},{p50:.3f},{p99:.3f}")
                rows.append((f"fig8/load{int(load*100)}/{pol}", us,
                             f"p50={p50:.2f};p99={p99:.2f}"))
    _csv("fig7_system_wide.csv", "load,policy,p50,p99", csv7)
    _csv("fig8_dcpair.csv", "load,policy,p50,p99", csv8)
    return rows


# ------------------------------------------------------------------ Figure 9
def fig9_workloads(scale="default") -> List[Row]:
    rows, csv = [], []
    for wl in ["websearch", "fbhdp", "alistorage"]:
        for pol in ["ecmp", "ucmp", "lcmp"]:
            spec = ExpSpec(topology="testbed8", workload=wl, load=0.3,
                           policy=pol, duration_us=_DUR[scale])
            stats, _, _, us = _run(spec)
            csv.append(f"{wl},{pol},{stats.p50:.3f},{stats.p99:.3f}")
            rows.append((f"fig9/{wl}/{pol}", us,
                         f"p50={stats.p50:.2f};p99={stats.p99:.2f}"))
    _csv("fig9_workloads.csv", "workload,policy,p50,p99", csv)
    return rows


# ----------------------------------------------------------------- Figure 10
def fig10_cc_orthogonality(scale="default") -> List[Row]:
    rows, csv = [], []
    for cc in ["dcqcn", "hpcc", "timely", "dctcp"]:
        for pol in ["ecmp", "ucmp", "lcmp"]:
            spec = ExpSpec(topology="testbed8", load=0.3, policy=pol, cc=cc,
                           duration_us=_DUR[scale])
            stats, _, _, us = _run(spec)
            csv.append(f"{cc},{pol},{stats.p50:.3f},{stats.p99:.3f}")
            rows.append((f"fig10/{cc}/{pol}", us,
                         f"p50={stats.p50:.2f};p99={stats.p99:.2f}"))
    _csv("fig10_cc.csv", "cc,policy,p50,p99", csv)
    return rows


# ----------------------------------------------------------------- Figure 11
def fig11_ablations(scale="default") -> List[Row]:
    """(a) rm-alpha/rm-beta; (b) global (alpha,beta); (c) (w_dl,w_lc);
    (d) (w_ql,w_tl,w_dp) — per-size-bucket p50/p99 on the testbed @30%."""
    rows = []
    variants = {
        # (a) component ablation
        "full": {},
        "rm-alpha": dict(select=SelectParams(alpha=0, beta=1)),
        "rm-beta": dict(select=SelectParams(alpha=3, beta=0)),
        # (b) global fusion weights
        "ab-1-1": dict(select=SelectParams(alpha=1, beta=1)),
        "ab-1-3": dict(select=SelectParams(alpha=1, beta=3)),
        # (c) path-quality weights
        "dl-1-1": dict(pathq=PathQParams(w_dl=1, w_lc=1)),
        "dl-1-3": dict(pathq=PathQParams(w_dl=1, w_lc=3)),
        # (d) congestion weights
        "cg-1-2-1": dict(congp=CongParams(w_ql=1, w_tl=2, w_dp=1)),
        "cg-1-1-2": dict(congp=CongParams(w_ql=1, w_tl=1, w_dp=2)),
    }
    csv = []
    for name, over in variants.items():
        spec = ExpSpec(topology="testbed8", load=0.3, policy="lcmp",
                       duration_us=_DUR[scale], **over)
        stats, _, _, us = _run(spec)
        buckets = stats.by_size_bucket(_SIZE_EDGES)
        for b, v in buckets.items():
            csv.append(f"{name},{b},{v['p50']:.3f},{v['p99']:.3f},{v['n']}")
        rows.append((f"fig11/{name}", us,
                     f"p50={stats.p50:.2f};p99={stats.p99:.2f}"))
    _csv("fig11_ablations.csv", "variant,size_bucket,p50,p99,n", csv)
    return rows


# --------------------------------------------------- failover (claim §3.4)
def failover_bench(scale="default") -> List[Row]:
    """Data-plane fast-failover: completion rate + tail with a 100G link
    killed mid-run (lazy re-hash, zero control-plane involvement)."""
    rows = []
    for pol in ["lcmp", "ecmp"]:
        spec = ExpSpec(topology="testbed8", load=0.3, policy=pol,
                       duration_us=_DUR[scale])
        t, table, flows, cfg = build_experiment(spec)
        cfg = dataclasses.replace(cfg, fail_link=12,
                                  fail_at_us=_DUR[scale] // 3)
        arrs, st = fluid.build(table, flows, cfg)
        t0 = time.perf_counter()
        final = fluid.run(arrs, st, cfg)
        us = (time.perf_counter() - t0) * 1e6
        stats = metrics.fct_stats(final, table, flows, cfg)
        rows.append((f"failover/{pol}", us,
                     f"completed={stats.completed}/{stats.offered};"
                     f"p99={stats.p99:.2f}"))
    return rows
