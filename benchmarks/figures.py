"""One benchmark per paper table/figure (LCMP, EuroSys'26).

Each function returns a list of CSV rows ``(name, us_per_call, derived)``
and writes full CSVs to benchmarks/out/. Every figure's grid now runs
through ``repro.netsim.sweep``: cells sharing a trace (same scenario /
cc / parameter overrides — policy, seed and workload are dynamic axes,
loads chunk on a padding budget) execute as a few compiled XLA
computations instead of a Python loop of re-traced ``fluid.run`` calls. ``us_per_call`` is therefore the group wall-clock
amortized over its cells; each figure also emits a ``<fig>/sweep``
summary row with the total wall-clock and group count, so the CSV stream
records the sweep-engine speedup over time.

Reduced-scale defaults (duration, cap_scale) keep the whole suite
CPU-tractable; pass scale="full" for paper-scale horizons. Pass
``sequential=True`` (or ``--sequential`` on benchmarks.run) to run the
pre-sweep per-cell loop — the before/after comparison baseline.
"""
from __future__ import annotations

import os
from typing import List, Tuple

import numpy as np

from repro.core.cong import CongParams
from repro.core.pathq import PathQParams
from repro.core.select import SelectParams
from repro.netsim.experiment import ExpSpec, build_world
from repro.netsim.sweep import run_sweep

OUT = os.path.join(os.path.dirname(__file__), "out")
Row = Tuple[str, float, str]

_DUR = {"quick": 300_000, "default": 400_000, "full": 1_500_000}
_SIZE_EDGES = [0, 3e3, 1e4, 3e4, 1e5, 1e6, 1e7, 1e9]

def _csv(name: str, header: str, rows: List[str]) -> None:
    os.makedirs(OUT, exist_ok=True)
    with open(os.path.join(OUT, name), "w") as f:
        f.write(header + "\n")
        f.writelines(r + "\n" for r in rows)


def _sweep(figname: str, specs: List[ExpSpec], sequential: bool):
    """Run a figure's grid through the sweep engine; returns (results,
    per-cell us, summary row)."""
    rep = run_sweep(specs, sequential=sequential)
    total_us = rep.wall_s * 1e6
    per_cell = total_us / max(rep.num_cells, 1)
    mode = "sequential" if sequential else "batched"
    summary = (f"{figname}/sweep", total_us,
               f"mode={mode};cells={rep.num_cells};groups={rep.num_groups}")
    return rep.results, per_cell, summary


# ------------------------------------------------------------------ Figure 1
def fig1_link_utilization(scale="default", sequential=False) -> List[Row]:
    """[Motivation] per-link utilization under ECMP/UCMP/LCMP, 8-DC, 30%."""
    longhaul = {"DC1-DC2": 0, "DC1-DC3": 4, "DC1-DC4": 8,
                "DC1-DC5": 12, "DC1-DC6": 16, "DC1-DC7": 20}
    pols = ["ecmp", "ucmp", "lcmp"]
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol,
                     duration_us=_DUR[scale]) for pol in pols]
    results, per_cell, summary = _sweep("fig1", specs, sequential)
    rows, csv = [summary], []
    for res in results:
        u = {k: float(res.util[i]) for k, i in longhaul.items()}
        csv += [f"{res.spec.policy},{k},{v:.4f}" for k, v in u.items()]
        rows.append((f"fig1/{res.spec.policy}", per_cell,
                     "util=" + "|".join(f"{v:.3f}" for v in u.values())))
    _csv("fig1_utilization.csv", "policy,link,utilization", csv)
    return rows


# ------------------------------------------------------------------ Figure 5
def fig5_testbed_fct(scale="default", sequential=False) -> List[Row]:
    """Median/P99 FCT slowdown, Web Search, 8-DC testbed, 30/50/80% load.

    Each load's 5-policy row shares one trace; loads chunk by flow count."""
    specs = [ExpSpec(topology="testbed8", load=load, policy=pol,
                     duration_us=_DUR[scale])
             for load in [0.3, 0.5, 0.8]
             for pol in ["ecmp", "ucmp", "redte", "lcmp", "lcmp_w"]]
    results, per_cell, summary = _sweep("fig5", specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        csv.append(f"{s.load},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                   f"{st.completed}")
        rows.append((f"fig5/load{int(s.load*100)}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    _csv("fig5_testbed.csv", "load,policy,p50,p99,completed", csv)
    return rows


# ------------------------------------------------------------------ Figure 6
def fig6_fidelity(scale="default", sequential=False) -> List[Row]:
    """[Simulator fidelity] The paper correlates testbed vs NS-3 (r>=0.95).
    Without hardware we check the analogous internal-consistency property:
    per-policy slowdowns correlate across independent seeds (determinism +
    stability of the simulation platform)."""
    cells = [(pol, load, seed)
             for pol in ["ecmp", "ucmp", "lcmp"]
             for load in [0.3, 0.5] for seed in (1, 2)]
    specs = [ExpSpec(topology="testbed8", load=load, policy=pol, seed=seed,
                     duration_us=_DUR["quick"]) for pol, load, seed in cells]
    results, _, summary = _sweep("fig6", specs, sequential)
    by = {cell: res.stats for cell, res in zip(cells, results)}
    xs, ys, csv = [], [], []
    for pol in ["ecmp", "ucmp", "lcmp"]:
        for load in [0.3, 0.5]:
            a, b = by[(pol, load, 1)], by[(pol, load, 2)]
            xs += [a.p50, a.p99]
            ys += [b.p50, b.p99]
            csv.append(f"{pol},{load},{a.p50:.3f},{b.p50:.3f},"
                       f"{a.p99:.3f},{b.p99:.3f}")
    r = float(np.corrcoef(np.log(xs), np.log(ys))[0, 1])
    _csv("fig6_fidelity.csv",
         "policy,load,p50_seed1,p50_seed2,p99_seed1,p99_seed2", csv)
    return [summary, ("fig6/seed-correlation", 0.0, f"pearson_log={r:.3f}")]


# -------------------------------------------------------------- Figures 7+8
def fig7_8_large_scale(scale="default", sequential=False) -> List[Row]:
    """13-DC all-to-all system-wide (Fig. 7) + the multi-path DC-pair case
    study (Fig. 8) extracted from the same runs."""
    specs = [ExpSpec(topology="bso13", load=load, policy=pol, pairs="all",
                     duration_us=_DUR[scale], cap_scale=0.0625)
             for load in [0.3, 0.5, 0.8]
             for pol in ["ecmp", "ucmp", "redte", "lcmp"]]
    results, per_cell, summary = _sweep("fig7_8", specs, sequential)
    _, table = build_world("bso13")
    multi = np.nonzero(table.pair_ncand >= 3)[0]
    rows, csv7, csv8 = [summary], [], []
    for res in results:
        s, st = res.spec, res.stats
        csv7.append(f"{s.load},{s.policy},{st.p50:.3f},{st.p99:.3f}")
        rows.append((f"fig7/load{int(s.load*100)}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
        # Fig 8: restrict to pairs with multiple near-equal candidates
        sel = np.isin(res.flows.pair_id, multi)
        done = res.final.done & sel
        if done.sum() > 20:
            prop = table.pair_ideal_prop[res.flows.pair_id].astype(float)
            cap = table.pair_ideal_cap[res.flows.pair_id] * 125.0 * s.cap_scale
            ideal = prop + res.flows.size_bytes / cap
            sl = np.maximum(res.final.fct_us[done] / ideal[done], 1)
            p50, p99 = np.percentile(sl, 50), np.percentile(sl, 99)
            csv8.append(f"{s.load},{s.policy},{p50:.3f},{p99:.3f}")
            rows.append((f"fig8/load{int(s.load*100)}/{s.policy}", per_cell,
                         f"p50={p50:.2f};p99={p99:.2f}"))
    _csv("fig7_system_wide.csv", "load,policy,p50,p99", csv7)
    _csv("fig8_dcpair.csv", "load,policy,p50,p99", csv8)
    return rows


# ------------------------------------------------------------------ Figure 9
def fig9_workloads(scale="default", sequential=False) -> List[Row]:
    """Workload generality: the 3-workload x 3-policy grid is one trace
    (workloads only change flow-table contents)."""
    specs = [ExpSpec(topology="testbed8", workload=wl, load=0.3, policy=pol,
                     duration_us=_DUR[scale])
             for wl in ["websearch", "fbhdp", "alistorage"]
             for pol in ["ecmp", "ucmp", "lcmp"]]
    results, per_cell, summary = _sweep("fig9", specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        csv.append(f"{s.workload},{s.policy},{st.p50:.3f},{st.p99:.3f}")
        rows.append((f"fig9/{s.workload}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    _csv("fig9_workloads.csv", "workload,policy,p50,p99", csv)
    return rows


# ----------------------------------------------------------------- Figure 10
def fig10_cc_orthogonality(scale="default", sequential=False) -> List[Row]:
    """CC orthogonality: cc is a static (trace-level) axis, so this grid
    compiles once per CC law and vmaps the policy axis inside each."""
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol, cc=cc,
                     duration_us=_DUR[scale])
             for cc in ["dcqcn", "hpcc", "timely", "dctcp"]
             for pol in ["ecmp", "ucmp", "lcmp"]]
    results, per_cell, summary = _sweep("fig10", specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        csv.append(f"{s.cc},{s.policy},{st.p50:.3f},{st.p99:.3f}")
        rows.append((f"fig10/{s.cc}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    _csv("fig10_cc.csv", "cc,policy,p50,p99", csv)
    return rows


# ----------------------------------------------------------------- Figure 11
def fig11_ablations(scale="default", sequential=False) -> List[Row]:
    """(a) rm-alpha/rm-beta; (b) global (alpha,beta); (c) (w_dl,w_lc);
    (d) (w_ql,w_tl,w_dp) — per-size-bucket p50/p99 on the testbed @30%.

    Parameter dataclasses are static (baked into the trace), so each
    variant is its own sweep group — the engine handles the degenerate
    1-cell-per-group grid transparently."""
    variants = {
        # (a) component ablation
        "full": {},
        "rm-alpha": dict(select=SelectParams(alpha=0, beta=1)),
        "rm-beta": dict(select=SelectParams(alpha=3, beta=0)),
        # (b) global fusion weights
        "ab-1-1": dict(select=SelectParams(alpha=1, beta=1)),
        "ab-1-3": dict(select=SelectParams(alpha=1, beta=3)),
        # (c) path-quality weights
        "dl-1-1": dict(pathq=PathQParams(w_dl=1, w_lc=1)),
        "dl-1-3": dict(pathq=PathQParams(w_dl=1, w_lc=3)),
        # (d) congestion weights
        "cg-1-2-1": dict(congp=CongParams(w_ql=1, w_tl=2, w_dp=1)),
        "cg-1-1-2": dict(congp=CongParams(w_ql=1, w_tl=1, w_dp=2)),
    }
    specs = [ExpSpec(topology="testbed8", load=0.3, policy="lcmp",
                     duration_us=_DUR[scale], **over)
             for over in variants.values()]
    results, per_cell, summary = _sweep("fig11", specs, sequential)
    rows, csv = [summary], []
    for name, res in zip(variants, results):
        st = res.stats
        for b, v in st.by_size_bucket(_SIZE_EDGES).items():
            csv.append(f"{name},{b},{v['p50']:.3f},{v['p99']:.3f},{v['n']}")
        rows.append((f"fig11/{name}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f}"))
    _csv("fig11_ablations.csv", "variant,size_bucket,p50,p99,n", csv)
    return rows


# --------------------------------------------------- failover (claim §3.4)
def failover_bench(scale="default", sequential=False) -> List[Row]:
    """Data-plane fast-failover: completion rate + tail with the 100G/5ms
    long-haul link killed a third into the run (lazy re-hash, zero
    control-plane involvement). Runs via the ``testbed8_failover``
    scenario — both policies share the schedule, so the pair is one
    sweep group."""
    fail_ms = _DUR[scale] // 3000
    specs = [ExpSpec(topology=f"testbed8_failover:fail_ms={fail_ms}",
                     load=0.3, policy=pol, duration_us=_DUR[scale])
             for pol in ["lcmp", "ecmp"]]
    results, per_cell, summary = _sweep("failover", specs, sequential)
    rows = [summary]
    for res in results:
        st = res.stats
        rows.append((f"failover/{res.spec.policy}", per_cell,
                     f"completed={st.completed}/{st.offered};"
                     f"p99={st.p99:.2f}"))
    return rows


# ------------------------------------------- staleness ablation (§7.3, new)
def staleness_ablation(scale="default", sequential=False) -> List[Row]:
    """[§7.3] Signal-staleness grid on the ``staleness`` scenario (a
    *remote* span of the good route silently degrades): sig_delay_scale
    x ctrl_period_us, with the policy axis dynamic inside each trace.
    Congestion-reactive policies (lcmp, lcmp_w) worsen as the routed
    signal ages; oblivious ecmp is exactly flat. Each CSV row also
    records the degraded route's *installed* C_path at horizon end; the
    ctrl_period_us=0 rows keep the build-time score while every live
    period shows the repriced one — the control-plane refresh
    demonstrably repricing the route, visible in the CSV itself."""
    # degrade early (1/5 of the run): the tail must be dominated by flows
    # that lived through the stale-signal window, not by generic load
    deg_ms = max(_DUR[scale] // 5000, 50)
    top = f"staleness:deg_ms={deg_ms}"
    grid = [(sds, per) for sds in (0.0, 1.0, 4.0)
            for per in (0, 50_000, 200_000)]
    specs = [ExpSpec(topology=top, load=0.5, policy=pol,
                     duration_us=_DUR[scale], seed=1,
                     sig_delay_scale=sds, ctrl_period_us=per)
             for sds, per in grid
             for pol in ["ecmp", "lcmp", "lcmp_w"]]
    results, per_cell, summary = _sweep("staleness", specs, sequential)
    scen, table = build_world(top)
    deg_link = scen.degrade_sched[0][0]
    deg_path = int(np.nonzero(
        (np.asarray(table.path_links) == deg_link).any(-1))[0][0])
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        cp = int(res.final.c_path[deg_path])
        csv.append(f"{s.sig_delay_scale:g},{s.ctrl_period_us},{s.policy},"
                   f"{st.p50:.3f},{st.p99:.3f},{cp}")
        rows.append((f"staleness/sds{s.sig_delay_scale:g}"
                     f"/cp{s.ctrl_period_us // 1000}ms/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f};cpath_deg={cp}"))
    _csv("staleness_ablation.csv",
         "sig_delay_scale,ctrl_period_us,policy,p50,p99,cpath_degraded", csv)
    return rows


# ------------------------------------------------- scenario showcase (new)
def scenarios_bench(scale="default", sequential=False) -> List[Row]:
    """Beyond-paper scenario regimes from the registry: a segmented
    long-haul mesh (MatchRDMA-style), silent capacity degradation on the
    13-DC backbone, and delay-asymmetry jitter on the testbed."""
    specs = [ExpSpec(topology=top, load=0.3, policy=pol,
                     duration_us=_DUR[scale], pairs=pairs,
                     cap_scale=cap_scale)
             for top, pairs, cap_scale in [
                 ("longhaul_mesh:routes=6,segs=3", "main", 0.125),
                 (f"bso13_degrade:at_ms={_DUR[scale] // 3000}", "all", 0.0625),
                 ("jitter:base=testbed8,frac=0.3", "main", 0.125),
             ]
             for pol in ["lcmp", "ecmp"]]
    results, per_cell, summary = _sweep("scenarios", specs, sequential)
    rows, csv = [summary], []
    for res in results:
        s, st = res.spec, res.stats
        name = s.topology.split(":")[0]
        csv.append(f"{name},{s.policy},{st.p50:.3f},{st.p99:.3f},"
                   f"{st.completed}")
        rows.append((f"scenarios/{name}/{s.policy}", per_cell,
                     f"p50={st.p50:.2f};p99={st.p99:.2f};"
                     f"completed={st.completed}/{st.offered}"))
    _csv("scenarios.csv", "scenario,policy,p50,p99,completed", csv)
    return rows
