"""Reproduce the paper's core result interactively: LCMP vs ECMP vs UCMP
on the 8-DC heterogeneous testbed (Fig. 5 direction) + the herd-effect
demo on a burst of simultaneous flows (paper challenge C3).

  PYTHONPATH=src python examples/routing_sim.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import select
from repro.netsim.experiment import ExpSpec, run_experiment

print("=== FCT slowdown on the 8-DC testbed, WebSearch @30% load ===")
for pol in ["ecmp", "ucmp", "lcmp", "lcmp_w"]:
    spec = ExpSpec(topology="testbed8", load=0.3, policy=pol,
                   duration_us=400_000)
    stats, util, _ = run_experiment(spec)
    print(f"  {pol:7s} p50={stats.p50:6.2f}  p99={stats.p99:7.2f}  "
          f"(completed {stats.completed})")

print("\n=== Herd mitigation: 1000 flows decide simultaneously ===")
fids = jnp.arange(1000, dtype=jnp.uint32) * jnp.uint32(2654435761)
c_path = jnp.array([10, 12, 15, 200, 220, 250])   # 3 good paths, 3 bad
c_cong = jnp.zeros(6, jnp.int32)
idx, _ = select.select_egress(fids, c_path, c_cong, jnp.ones(6, bool))
print("  choice histogram:", np.bincount(np.asarray(idx), minlength=6))
print("  (greedy min-cost would pile all 1000 onto path 0)")
