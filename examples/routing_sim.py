"""Reproduce the paper's core result interactively: LCMP vs ECMP vs UCMP
on the 8-DC heterogeneous testbed (Fig. 5 direction) + the herd-effect
demo on a burst of simultaneous flows (paper challenge C3), now driven
through the batched sweep engine — the whole policy comparison is ONE
XLA computation — plus a beyond-paper scenario sweep from the registry.

  PYTHONPATH=src python examples/routing_sim.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import select
from repro.netsim.experiment import ExpSpec
from repro.netsim.sweep import run_sweep

print("=== FCT slowdown on the 8-DC testbed, WebSearch @30% load ===")
specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol,
                 duration_us=400_000)
         for pol in ["ecmp", "ucmp", "lcmp", "lcmp_w"]]
report = run_sweep(specs)   # 4 cells, one trace, one dispatch
for cell in report:
    st = cell.stats
    print(f"  {cell.spec.policy:7s} p50={st.p50:6.2f}  p99={st.p99:7.2f}  "
          f"(completed {st.completed})")
print(f"  [{report.num_cells} cells in {report.num_groups} compiled "
      f"group(s), {report.wall_s:.1f}s]")

print("\n=== Scenario registry: segmented long-haul mesh + failover ===")
specs = [ExpSpec(topology=top, load=0.3, policy=pol, duration_us=300_000)
         for top in ["longhaul_mesh:routes=6,segs=3",
                     "testbed8_failover:fail_ms=100"]
         for pol in ["lcmp", "ecmp"]]
for cell in run_sweep(specs):
    st = cell.stats
    name = cell.spec.topology.split(":")[0]
    print(f"  {name:18s} {cell.spec.policy:5s} p50={st.p50:6.2f} "
          f"p99={st.p99:7.2f}  completed {st.completed}/{st.offered}")

print("\n=== Signal staleness (§7.3): how fresh must LCMP's view be? ===")
# A *remote* span of the good route silently degrades; the ingress only
# learns about it one backward propagation delay later (sig_delay_scale
# scales that delay; 0 = oracle) and its installed C_path table only
# reprices at the next control-plane refresh (ctrl_period_us; 0 = frozen
# build-time table). ECMP never reads either signal — its cells are the
# flat control.
specs = [ExpSpec(topology="staleness:deg_ms=60", load=0.5, policy=pol,
                 duration_us=300_000, seed=1,
                 sig_delay_scale=sds, ctrl_period_us=per)
         for sds, per in [(0.0, 50_000), (1.0, 50_000),
                          (4.0, 50_000), (1.0, 0)]
         for pol in ["lcmp", "ecmp"]]
for cell in run_sweep(specs):
    s, st = cell.spec, cell.stats
    ctrl = "frozen" if s.ctrl_period_us == 0 else f"{s.ctrl_period_us//1000}ms"
    print(f"  delay x{s.sig_delay_scale:g}  ctrl={ctrl:6s} {s.policy:5s} "
          f"p50={st.p50:6.2f}  p99={st.p99:7.2f}")

print("\n=== Herd mitigation: 1000 flows decide simultaneously ===")
fids = jnp.arange(1000, dtype=jnp.uint32) * jnp.uint32(2654435761)
c_path = jnp.array([10, 12, 15, 200, 220, 250])   # 3 good paths, 3 bad
c_cong = jnp.zeros(6, jnp.int32)
idx, _ = select.select_egress(fids, c_path, c_cong, jnp.ones(6, bool))
print("  choice histogram:", np.bincount(np.asarray(idx), minlength=6))
print("  (greedy min-cost would pile all 1000 onto path 0)")
