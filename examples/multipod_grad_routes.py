"""LCMP as the cross-pod collective scheduler: run a sharded train step
where gradient buckets are LCMP-routed over candidate route programs,
then fail a route and watch the lazy re-bind (fast-failover).

Runs in a subprocess with 8 simulated devices (2 pods x 2 data x 2 model).

  PYTHONPATH=src python examples/multipod_grad_routes.py
"""
import os
import subprocess
import sys

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import repro  # installs the jax.shard_map forward-compat alias on jax 0.4.x
import jax, jax.numpy as jnp, numpy as np
from jax import shard_map
from jax.sharding import PartitionSpec as P
from repro.dist import lcmp_collectives as lc

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
grads = {f"bucket{i}": jnp.ones((2, 256)) * (i + 1) for i in range(6)}

ids = lc._fmix32_host(np.arange(1, 7, dtype=np.uint32))
print("route binding (all alive):", lc.schedule_buckets(ids))

def reduce_fn(g):
    return lc.lcmp_pod_reduce(g, "pod")
f = shard_map(reduce_fn, mesh=mesh, in_specs=P("pod"), out_specs=P("pod"),
              check_vma=False)
out = jax.jit(f)(jax.tree.map(lambda x: x, grads))
print("reduced ok:", all(bool(jnp.all(v == v[0, 0])) for v in out.values()))

# kill route 0 (telemetry marks the direct all-reduce path dead)
lc.set_route_liveness([False, True, True])
print("route binding (route0 dead):", lc.schedule_buckets(ids))
'''
env = dict(os.environ, PYTHONPATH="src")
subprocess.run([sys.executable, "-c", SCRIPT], env=env, check=True)
print("multipod_grad_routes OK")
