"""Quickstart: train a small LM end-to-end with checkpoint/resume, then
decode from it. Runs on CPU in ~a minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import subprocess
import sys
import tempfile

ck = tempfile.mkdtemp(prefix="repro-ck-")

# 1) train 30 steps, checkpointing every 10
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "qwen3_4b", "--smoke", "--steps", "30",
                "--batch", "4", "--seq", "64", "--ckpt", ck,
                "--ckpt-every", "10", "--log-every", "5"],
               check=True)

# 2) kill/restart: resume from step 30 checkpoint and continue to 40
subprocess.run([sys.executable, "-m", "repro.launch.train",
                "--arch", "qwen3_4b", "--smoke", "--steps", "40",
                "--batch", "4", "--seq", "64", "--ckpt", ck,
                "--resume", "--log-every", "5"],
               check=True)

# 3) serve a few tokens
subprocess.run([sys.executable, "-m", "repro.launch.serve",
                "--arch", "qwen3_4b", "--smoke", "--batch", "2",
                "--prompt-len", "16", "--gen", "16"],
               check=True)
print("quickstart OK")
