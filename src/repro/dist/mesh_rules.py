"""FSDP x TP sharding rules over the named production mesh axes.

``Rules`` maps every pytree the training/serving stack materializes
(params, optimizer state, train batches, decode caches) to logical
``PartitionSpec`` trees:

- ``model`` (tensor parallel): the output-feature dim of column-parallel
  projections (wq/wk/wv, w_gate/w_up, in_proj, dt_proj), the
  input-feature dim of row-parallel projections (wo, out_proj, w_down),
  and the vocab dim of embed/lm_head;
- ``data`` (FSDP): one remaining weight dim per leaf (largest divisible)
  plus the batch dim of inputs and caches;
- ``pod`` (data parallel across pods): batch only — parameters stay
  replicated across pods and gradients cross the long haul through
  ``repro.dist.lcmp_collectives`` instead of implicit all-reduces.

Placement is validated leaf-by-leaf: an axis is only assigned to a dim
it divides, so every ``repro.models.arch`` config shards cleanly on any
mesh (falling back to replication for a dim, never erroring). Leaves
stacked over the scanned layer axis (``layers`` / ``enc_layers``) never
shard dim 0.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import PartitionSpec as P

# leaf name -> which dim carries the tensor-parallel "model" axis
_TP_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "dt_proj"}
_TP_PENULT = {"wo", "out_proj", "w_down"}
_TP_VOCAB = {"embed", "lm_head"}
_STACKED = {"layers", "enc_layers"}       # leading dim = scanned layer axis


def _key_name(k) -> str:
    return str(getattr(k, "key", getattr(k, "name", k)))


def axis_sizes_of(mesh) -> Dict[str, int]:
    """{axis_name: size} for a jax Mesh (the Rules constructor input)."""
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_rules(cfg, mesh) -> "Rules":
    return Rules(cfg, axis_sizes_of(mesh))


class Rules:
    """Spec builders bound to one arch config + one mesh shape."""

    def __init__(self, cfg, axis_sizes: Dict[str, int]):
        self.cfg = cfg
        self.axis_sizes = dict(axis_sizes)
        self.data = int(axis_sizes.get("data", 1))
        self.model = int(axis_sizes.get("model", 1))
        self.pod = int(axis_sizes.get("pod", 1))

    # ------------------------------------------------------------ batch
    @property
    def _dp_size(self) -> int:
        return self.pod * self.data

    def _batch_axes(self, batch: int):
        """Axes for a batch dim (pods are plain data-parallel for inputs)."""
        if self._dp_size <= 1 or batch % self._dp_size != 0:
            return None
        return ("pod", "data") if self.pod > 1 else "data"

    def train_batch_specs(self, batch: int, seq: int) -> Dict[str, P]:
        b = self._batch_axes(batch)
        return {"tokens": P(b, None), "labels": P(b, None),
                "extra": P(b, None, None)}

    def decode_token_spec(self, batch: int) -> P:
        return P(self._batch_axes(batch), None)

    # ----------------------------------------------------------- params
    def _leaf_spec(self, path, shape) -> P:
        keys = [_key_name(k) for k in path]
        name = keys[-1] if keys else ""
        ndim = len(shape)
        spec = [None] * ndim
        reserved = {0} if keys and keys[0] in _STACKED and ndim else set()

        def fits(dim: int, size: int) -> bool:
            return (size > 1 and 0 <= dim < ndim and dim not in reserved
                    and spec[dim] is None and shape[dim] % size == 0)

        tp = None
        if name in _TP_LAST:
            tp = ndim - 1
        elif name in _TP_PENULT:
            tp = ndim - 2
        elif name in _TP_VOCAB:
            tp = 0
        if tp is not None and fits(tp, self.model):
            spec[tp] = "model"
            reserved.add(tp)

        if self.data > 1:
            cands = [d for d in range(ndim) if fits(d, self.data)]
            if cands:
                spec[max(cands, key=lambda d: shape[d])] = "data"
        return P(*spec)

    def param_specs(self, params):
        """PartitionSpec tree matching ``params`` (arrays or
        ShapeDtypeStructs) leaf for leaf."""
        leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
        return jax.tree_util.tree_unflatten(
            treedef,
            [self._leaf_spec(path, leaf.shape) for path, leaf in leaves])

    # ------------------------------------------------------------ cache
    def _cache_leaf_spec(self, path, shape) -> P:
        keys = [_key_name(k) for k in path]
        name = keys[-1] if keys else ""
        ndim = len(shape)
        spec = [None] * ndim
        b = self._batch_axes(shape[1]) if ndim >= 2 else None
        if b is not None and ndim >= 2:
            spec[1] = b
        # head / state-channel dim gets tensor parallelism where it divides
        tp = None
        if name in ("k", "v") and ndim == 5:
            tp = 3                        # (L, B, S, Kv, hd): kv heads
        elif name == "conv" and ndim == 4:
            tp = 3                        # (L, B, 3, Di): channels
        elif name == "ssm" and ndim >= 4:
            tp = 2                        # (L, B, Di|H, ...): inner dim
        if (tp is not None and self.model > 1 and spec[tp] is None
                and shape[tp] % self.model == 0):
            spec[tp] = "model"
        return P(*spec)

    def cache_specs(self, cache):
        leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
        return jax.tree_util.tree_unflatten(
            treedef,
            [self._cache_leaf_spec(path, leaf.shape) for path, leaf in leaves])
