"""Distribution layer for the multi-pod training stack.

- ``mesh_rules``       : FSDP x TP PartitionSpec trees for every pytree
  the stack materializes (params, optimizer, batches, decode caches).
- ``lcmp_collectives`` : LCMP-scheduled cross-pod gradient reduction
  (bucketed reduce-scatter/all-gather over the ``pod`` axis, buckets
  route-bound by the paper's fused cost) plus the route telemetry
  registers the launcher feeds with per-step wall times.
- ``compress``         : int8 + per-block-scale wire format (with error
  feedback) over the ``repro.kernels.qsr_int8`` Pallas kernel for the
  4x wire-byte ``lcmp_int8`` path.

The layer contract is pinned by ``tests/test_dist.py``: sharded step ==
single-device step, ``lcmp_pod_reduce`` == pmean, compressed reduce
error <= 2.1 x scale, and elastic checkpoint restore across meshes.
"""
