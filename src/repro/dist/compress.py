"""int8 + per-block-scale wire format for cross-pod gradient buckets.

Wire layout for a flat f32 vector of N elements:
  q      (Np,)         int8   stochastically-rounded mantissas
  scales (Np/1024,)    f32    per-1024-element block scales (amax/127)
with Np = N rounded up to a 1024 multiple, so the wire carries
``N + 4*N/1024`` bytes instead of ``4*N`` — a 3.98x reduction on the
DCI long haul (DESIGN §5; the ``lcmp_int8`` train path).

Quantization runs through the Pallas kernel ``repro.kernels.qsr_int8``
(blockwise amax, stochastic rounding from caller-supplied counter bits,
so the wire format is deterministic and testable). Error feedback
(``encode_ef``) returns the representation residual so the caller can
fold it into the *next* step's gradient, making the compression
unbiased over time (standard EF-SGD).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.select import fmix32
from repro.kernels.qsr_int8 import BLOCK, qsr_dequant, qsr_int8


class Wire(NamedTuple):
    """One compressed bucket as it crosses the long haul."""
    q: jnp.ndarray        # (Np,) int8
    scales: jnp.ndarray   # (Np/BLOCK,) f32
    orig_len: int         # static: valid prefix of q (rest is padding)


def padded_len(n: int) -> int:
    return -(-n // BLOCK) * BLOCK


def rand_bits(n: int, seed, salt=0) -> jnp.ndarray:
    """Counter-based uint32 stream for the stochastic rounding (pure
    function of (seed, salt, position): identical across retraces)."""
    ctr = jnp.arange(n, dtype=jnp.uint32) * jnp.uint32(2654435761)
    mix = fmix32(jnp.asarray(salt).astype(jnp.uint32) + jnp.uint32(1))
    return fmix32(ctr ^ jnp.asarray(seed).astype(jnp.uint32) ^ mix)


def encode(x: jnp.ndarray, *, seed=0, salt=0) -> Wire:
    """Flat f32 (N,) -> Wire. Pads with zeros up to the block size."""
    n = x.shape[0]
    np_ = padded_len(n)
    xf = x.astype(jnp.float32)
    if np_ != n:
        xf = jnp.concatenate([xf, jnp.zeros((np_ - n,), jnp.float32)])
    q, scales = qsr_int8(xf, rand_bits(np_, seed, salt))
    return Wire(q=q, scales=scales, orig_len=n)


def decode(w: Wire) -> jnp.ndarray:
    return qsr_dequant(w.q, w.scales)[: w.orig_len]


def wire_bytes(w: Wire) -> int:
    return int(w.q.size) + 4 * int(w.scales.size)


def encode_ef(x: jnp.ndarray, residual: jnp.ndarray, *, seed=0,
              salt=0) -> tuple:
    """Error-feedback encode: compress ``x + residual`` and return the
    new residual ``(x + residual) - decode(wire)`` to carry forward."""
    y = x.astype(jnp.float32) + residual.astype(jnp.float32)
    w = encode(y, seed=seed, salt=salt)
    return w, y - decode(w)
