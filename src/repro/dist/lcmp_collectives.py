"""LCMP-scheduled cross-pod collectives: the paper's router applied to
gradient buckets on the inter-datacenter long haul.

The inter-pod fabric is modeled as ``NUM_ROUTES`` candidate *route
programs* (direct DCI, fallback DCI, transit-pod detour) with a static
path-quality score per route (``repro.core.pathq`` semantics:
delay-biased, fat-link-friendly, host-side integer mirror) and a
telemetry register file mirroring the on-switch congestion estimator of
``repro.core.cong`` — Q/T/D registers fed with observed per-step wall
times, so a persistently slow route (straggler trend) scores high and
gets demoted for *future* buckets.

``lcmp_pod_reduce`` chops the flat gradient vector into fixed-size
buckets and binds each bucket to a route with the exact two-stage LCMP
selection (fused cost C = alpha*C_path + beta*C_cong, keep the
lower-cost half of the *live* routes, fmix32-hash inside the kept set —
dead routes are skipped entirely: the lazy fast-failover of paper
§3.4). The reduction itself executes as ONE fused shard-map-safe
reduce-scatter / all-gather mean over the named ``pod`` mesh axis (wire
bytes identical to per-bucket collectives, but the traced program stays
O(1) in bucket count — a billion-parameter gradient doesn't unroll into
tens of thousands of collectives). Optionally int8-compressed on the
wire (``repro.dist.compress`` over the ``kernels.qsr_int8`` Pallas
kernel: quantize -> all_to_all -> partial-mean -> re-quantize ->
all_gather, <= 2 quantization steps of error end to end).

Route binding is metadata in this single-process reproduction — every
bucket ultimately shares the same XLA collective — but it is recorded
per bucket/route in ``_TELEMETRY.route_bytes`` at trace time so
examples and tests can observe the scheduling decisions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist import compress as comp
from repro.kernels.qsr_int8 import BLOCK, qsr_dequant, qsr_int8

# Candidate inter-pod route programs (one-way propagation us, capacity
# Gbps): direct DCI, fallback DCI, transit-pod detour.
NUM_ROUTES = 3
ROUTE_PROP_US = np.array([5_000, 20_000, 45_000], np.int64)
ROUTE_CAP_GBPS = np.array([400, 200, 100], np.int64)
ALPHA, BETA = 3, 1            # paper §5/§7 fused-cost weights
BUCKET_ELEMS = 1 << 16        # 256 KiB f32 buckets on the wire


def _fmix32_host(x: np.ndarray) -> np.ndarray:
    """MurmurHash3 finalizer over uint32 (host-side twin of
    ``repro.core.select.fmix32``)."""
    x = np.asarray(x, np.uint32).copy()
    x ^= x >> np.uint32(16)
    x = (x * np.uint32(0x85EBCA6B)).astype(np.uint32)
    x ^= x >> np.uint32(13)
    x = (x * np.uint32(0xC2B2AE35)).astype(np.uint32)
    x ^= x >> np.uint32(16)
    return x


def _route_cpath() -> np.ndarray:
    """Static per-route C_path, integer mirror of ``core.pathq`` Eq. 2:
    delayScore = min(us >> 8, 255); capacity classes of 40 Gbps, fatter
    link -> lower cost; fused with (w_dl, w_lc) = (3, 1), >> 2."""
    d = np.minimum(ROUTE_PROP_US >> 8, 255)
    cls = np.minimum(ROUTE_CAP_GBPS // 40, 10)
    lc_score = ((10 - cls) * 255) // 10
    return np.minimum((3 * d + lc_score) >> 2, 255)


C_PATH = _route_cpath()


class RouteTelemetry:
    """Host-side per-route register file (the 24 B/port registers of
    ``core.cong``, §3.3): EWMA trend (Eq. 3), level and persistence,
    driven by per-step wall-time observations from the launcher."""

    EWMA_K = 3          # Eq. 3 shift
    HIGH_MS = 512       # wall-time level treated as "congested"

    def __init__(self, n: int = NUM_ROUTES):
        self.n = n
        self.reset()

    def reset(self):
        self.cur = np.zeros(self.n, np.int64)
        self.trend = np.zeros(self.n, np.int64)
        self.dur = np.zeros(self.n, np.int64)
        self.last_step = -1
        self.alive = np.ones(self.n, bool)
        self.route_bytes = np.zeros(self.n, np.int64)

    def observe(self, ms, step: int):
        """Feed one per-route wall-time sample (ms) at train ``step``."""
        ms = np.asarray(ms, np.int64)
        delta = ms - self.cur
        self.trend = (self.trend - (self.trend >> self.EWMA_K)
                      + (delta >> self.EWMA_K))
        self.cur = ms
        self.dur = np.where(ms >= self.HIGH_MS, self.dur + 1, self.dur >> 1)
        self.last_step = int(step)

    def observe_measured(self, bucket_ms, bucket_routes, step: int):
        """Feed *externally measured* per-bucket wall times (ms) into the
        Q/T/D registers — the co-simulation seam (``repro.cosim``): bucket
        times come from the netsim engines instead of the launcher's
        synthetic wall clock. ``bucket_routes`` is the route each bucket
        was bound to (``schedule_buckets`` output; -1 = unrouted, the
        sample is dropped). A route's sample is the MAX over its buckets
        (barrier semantics — the straggler bucket is what the step
        waits on); a route with no bucket this step holds its current
        level, so its delta is 0 and the trend register decays exactly as
        an idle port's would."""
        bucket_ms = np.asarray(bucket_ms, np.int64).reshape(-1)
        routes = np.asarray(bucket_routes, np.int64).reshape(-1)
        if bucket_ms.shape != routes.shape:
            raise ValueError(f"bucket_ms {bucket_ms.shape} and "
                             f"bucket_routes {routes.shape} must align")
        ok = (routes >= 0) & (routes < self.n)
        # a sampled route's level is its straggler bucket, even when that
        # is *below* the held level (recovery must be observable too)
        slow = np.full(self.n, -(1 << 60), np.int64)
        np.maximum.at(slow, routes[ok], bucket_ms[ok])
        self.observe(np.where(slow > -(1 << 60), slow, self.cur), step)

    def cong_scores(self) -> np.ndarray:
        """C_cong per route in [0, 255] (Eqs. 4-5 shape: (2Q+T+D) >> 2)."""
        q = np.minimum(self.cur >> 2, 255)
        t = np.minimum(np.maximum(self.trend, 0), 255)
        d = np.minimum(self.dur, 255)
        return np.minimum((2 * q + t + d) >> 2, 255).astype(np.int64)


_TELEMETRY = RouteTelemetry()


def set_route_liveness(alive) -> None:
    """Control-plane liveness update (route withdrawal / fast-failover)."""
    alive = np.asarray(alive, bool).copy()
    assert alive.shape == (_TELEMETRY.n,), alive.shape
    _TELEMETRY.alive = alive


def schedule_buckets(bucket_ids: np.ndarray) -> np.ndarray:
    """Two-stage LCMP selection over routes for a batch of bucket ids
    (``core.select.select_egress`` semantics, host-side): fused cost,
    keep the lower-cost half of live routes (>= 1), fmix32-hash each
    bucket id inside the kept set. Returns -1 when no route is live."""
    ids = np.asarray(bucket_ids, np.uint32)
    cost = ALPHA * C_PATH + BETA * _TELEMETRY.cong_scores()
    live = np.nonzero(_TELEMETRY.alive)[0]
    if live.size == 0:
        return np.full(ids.shape, -1, np.int64)
    order = live[np.argsort(cost[live], kind="stable")]
    keep = order[: max(1, (live.size + 1) // 2)]
    return keep[_fmix32_host(ids) % np.uint32(len(keep))].astype(np.int64)


# ----------------------------------------------------------------- reduce
def _axis_size_or_none(axis):
    """Size of a bound named axis, or None outside shard_map/pmap (the
    1-device no-op path)."""
    if axis is None:
        return None
    try:
        return jax.lax.psum(1, axis)
    except NameError:
        return None


def _reduce_flat_f32(seg: jnp.ndarray, axis, n: int) -> jnp.ndarray:
    """Exact flat-vector mean over ``axis``: reduce-scatter + all-gather."""
    m = seg.shape[0]
    pad = (-m) % n
    if pad:
        seg = jnp.concatenate([seg, jnp.zeros((pad,), seg.dtype)])
    y = jax.lax.psum_scatter(seg, axis, scatter_dimension=0, tiled=True) / n
    return jax.lax.all_gather(y, axis, tiled=True)[:m]


def _reduce_flat_int8(seg: jnp.ndarray, axis, n: int,
                      seed: int) -> jnp.ndarray:
    """Compressed flat-vector mean: local quantize -> all_to_all (the
    reduce-scatter leg) -> dequant + partial mean -> re-quantize ->
    all_gather. Both wire legs carry int8 + per-1024 f32 scales."""
    m = seg.shape[0]
    chunk = -(-m // n)                  # per-pod chunk ...
    chunk = -(-chunk // BLOCK) * BLOCK  # ... rounded up to the scale block
    mp = n * chunk
    if mp != m:
        seg = jnp.concatenate([seg, jnp.zeros((mp - m,), jnp.float32)])
    me = jax.lax.axis_index(axis)

    q, s = qsr_int8(seg, comp.rand_bits(mp, seed, salt=me))
    q2 = jax.lax.all_to_all(q.reshape(n, chunk), axis,
                            split_axis=0, concat_axis=0, tiled=True)
    s2 = jax.lax.all_to_all(s.reshape(n, chunk // BLOCK), axis,
                            split_axis=0, concat_axis=0, tiled=True)
    part = qsr_dequant(q2.reshape(-1), s2.reshape(-1)).reshape(n, chunk)
    mean_chunk = part.mean(0)

    qm, sm = qsr_int8(mean_chunk, comp.rand_bits(chunk, seed ^ 0x5851F42D,
                                                 salt=me))
    qg = jax.lax.all_gather(qm, axis, tiled=True)
    sg = jax.lax.all_gather(sm, axis, tiled=True)
    return qsr_dequant(qg, sg)[:m]


def lcmp_pod_reduce(tree, axis, compress: bool = False):
    """Mean-reduce a gradient pytree over the named ``axis`` (== pmean),
    as LCMP-scheduled fixed-size buckets. No-op when ``axis`` is None or
    unbound (single-pod / 1-device runs).

    Must be called under shard_map/pmap with ``axis`` in scope; with
    ``compress=True`` the wire is int8 (4x fewer bytes, error bounded by
    2 quantization steps — see tests/test_dist.py)."""
    n = _axis_size_or_none(axis)
    if n is None or n == 1:
        return tree
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    dtypes = [l.dtype for l in leaves]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])
    total = int(flat.shape[0])

    # bucket->route binding + wire accounting (host metadata; the traced
    # reduction below is one fused collective regardless of bucket count)
    nb = -(-total // BUCKET_ELEMS)
    ids = _fmix32_host(np.arange(nb, dtype=np.uint32) + np.uint32(1))
    routes = schedule_buckets(ids)
    for b in range(nb):
        blen = min((b + 1) * BUCKET_ELEMS, total) - b * BUCKET_ELEMS
        wire = blen + 4 * (-(-blen // BLOCK)) if compress else 4 * blen
        if routes[b] >= 0:
            _TELEMETRY.route_bytes[int(routes[b])] += wire

    if compress:
        out = _reduce_flat_int8(flat, axis, n, seed=int(ids[0]))
    else:
        out = _reduce_flat_f32(flat, axis, n)
    offs = np.concatenate([[0], np.cumsum(sizes)])
    new_leaves = [out[offs[i]:offs[i + 1]].reshape(shapes[i]).astype(dtypes[i])
                  for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, new_leaves)
