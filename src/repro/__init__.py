"""repro — LCMP reproduction package.

Importing ``repro`` installs one forward-compat alias: newer jax exposes
``jax.shard_map(..., check_vma=)`` at the top level, while the pinned
jax 0.4.x only ships ``jax.experimental.shard_map.shard_map(...,
check_rep=)``. Call sites (and the test suite) use the new spelling, so
bridge it here once instead of try/excepting at every import site.
"""
import jax as _jax

if not hasattr(_jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    def _compat_shard_map(f=None, *, mesh, in_specs, out_specs,
                          check_vma=None, check_rep=None, **kw):
        if check_rep is None:
            check_rep = True if check_vma is None else check_vma
        if f is None:
            return lambda g: _compat_shard_map(
                g, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_rep, **kw)
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep, **kw)

    _jax.shard_map = _compat_shard_map
