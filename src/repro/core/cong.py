"""Realtime on-switch congestion estimator (paper §3.3).

Per egress port the switch keeps five registers (paper §4 storage
accounting: queueCur, queuePrev, trend, durCnt, lastSample = 24 B/port).
A lightweight monitor samples queue depth at a modest cadence and derives
three 8-bit signals:

- Q : instantaneous queue level  (qThresh lookup -> levelScore)
- T : short-term trend           (shift-based EWMA, Eq. 3, normalized by
                                  per-rate trend thresholds; <=0 -> 0)
- D : duration/persistence       (counter, +1 above high-water Q level,
                                  halves otherwise; right-shifted)

``C_cong = min((w_ql*Q + w_tl*T + w_dp*D) >> S_cong, 255)``   (Eqs. 4-5)

Everything is int32 and shift-based — bit-compatible with the 32-bit
switch registers the paper budgets. Queue depths are in 1 KiB *cells*
(see tables.py). State is a struct-of-arrays over ports so one call
updates a whole switch (or a fleet, with a leading switch axis).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tables import SCORE_MAX, SwitchTables


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CongParams:
    """Integer weights/shifts. Defaults = paper §7.4 recommended (2,1,1)."""
    w_ql: int = dataclasses.field(default=2, metadata=dict(static=True))
    w_tl: int = dataclasses.field(default=1, metadata=dict(static=True))
    w_dp: int = dataclasses.field(default=1, metadata=dict(static=True))
    ewma_k: int = dataclasses.field(default=3, metadata=dict(static=True))   # Eq. 3 K
    dur_shift: int = dataclasses.field(default=2, metadata=dict(static=True))

    @property
    def s_cong(self) -> int:
        total = self.w_ql + self.w_tl + self.w_dp
        return max(total - 1, 0).bit_length()


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CongState:
    """Per-port registers (struct-of-arrays, shape (..., num_ports))."""
    queue_cur: jnp.ndarray    # int32 cells   (last sampled)
    queue_prev: jnp.ndarray   # int32 cells   (previous sample)
    trend: jnp.ndarray        # int32 EWMA accumulator (cells/interval)
    dur_cnt: jnp.ndarray      # int32 persistence counter
    last_sample: jnp.ndarray  # int32 microseconds

    @classmethod
    def init(cls, num_ports: int, shape=()) -> "CongState":
        s = tuple(shape) + (num_ports,)
        z = jnp.zeros(s, jnp.int32)
        return cls(queue_cur=z, queue_prev=z, trend=z, dur_cnt=z, last_sample=z)


def _searchsorted_rows(thresh: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Row-wise searchsorted: thresh (..., B), x (...,) -> level counts."""
    return (thresh <= x[..., None]).sum(-1).astype(jnp.int32)


def monitor_update(state: CongState, queue_cells: jnp.ndarray, now_us: jnp.ndarray,
                   tables: SwitchTables, params: CongParams = CongParams()) -> CongState:
    """One monitor pass (paper workflow step 1 "Refresh congestion state").

    ``queue_cells`` are the freshly sampled per-port egress queue depths
    (1 KiB cells). Trend normalization uses the observed sampling interval
    implicitly: the EWMA accumulates per-sample deltas, and the per-rate
    ``trend_thresh`` tables were built for the nominal cadence; modest
    cadence jitter shifts levels by at most one (paper: "robust to modest
    variations in sampling frequency").
    """
    q = jnp.asarray(queue_cells, jnp.int32)
    delta = q - state.queue_cur
    k = params.ewma_k
    # Eq. (3): T = T_old - (T_old >> K) + (delta >> K)  (arithmetic shifts)
    trend = state.trend - jnp.right_shift(state.trend, k) + jnp.right_shift(delta, k)

    q_level = _searchsorted_rows(tables.q_thresh, q)
    above = q_level >= tables.high_water_level
    dur = jnp.where(above, state.dur_cnt + 1, jnp.right_shift(state.dur_cnt, 1))

    return CongState(
        queue_cur=q,
        queue_prev=state.queue_cur,
        trend=trend,
        dur_cnt=dur.astype(jnp.int32),
        last_sample=jnp.broadcast_to(jnp.asarray(now_us, jnp.int32),
                                     state.last_sample.shape),
    )


def cong_signals(state: CongState, tables: SwitchTables,
                 params: CongParams = CongParams()):
    """Derive the quantized (Q, T, D) score triple from current registers."""
    q_level = _searchsorted_rows(tables.q_thresh, state.queue_cur)
    q_score = tables.level_score[q_level]

    t_level = _searchsorted_rows(tables.trend_thresh, state.trend)
    t_score = jnp.where(state.trend > 0, tables.level_score[t_level], 0)

    d_score = jnp.minimum(jnp.right_shift(state.dur_cnt, params.dur_shift), SCORE_MAX)
    return q_score.astype(jnp.int32), t_score.astype(jnp.int32), d_score.astype(jnp.int32)


def calc_cong_cost(state: CongState, tables: SwitchTables,
                   params: CongParams = CongParams()) -> jnp.ndarray:
    """Eqs. (4)-(5): fused, normalized per-port C_cong in [0, 255]."""
    q, t, d = cong_signals(state, tables, params)
    fused = params.w_ql * q + params.w_tl * t + params.w_dp * d
    return jnp.minimum(jnp.right_shift(fused, params.s_cong), SCORE_MAX).astype(jnp.int32)
