"""repro.core — the paper's contribution: LCMP cost-fusion routing.

Public API:
  tables     : control-plane bootstrap vectors (Fig. 3)
  pathq      : Alg. 1/2 + Eq. 2 path-quality scores
  cong       : Q/T/D on-switch congestion estimator (Eqs. 3-5)
  select     : Eq. 1 fused cost + diversity-preserving selection (§3.4)
  flowcache  : per-flow stickiness, GC, lazy fast-failover
  switchd    : the composed DCI switch state machine (Fig. 2)
  baselines  : ECMP / WCMP / UCMP / RedTE-like comparison policies
"""
from repro.core.tables import SwitchTables, bootstrap_tables, level_score_table
from repro.core.pathq import PathQParams, calc_delay_cost, calc_linkcap_cost, calc_path_quality
from repro.core.cong import CongParams, CongState, monitor_update, cong_signals, calc_cong_cost
from repro.core.select import SelectParams, fused_cost, select_egress, ecmp_select, fmix32
from repro.core.flowcache import FlowCache
from repro.core.switchd import (SwitchParams, SwitchState, make_switch,
                                monitor_tick, route_batch, gc_tick,
                                candidate_costs, set_port_liveness)

__all__ = [
    "SwitchTables", "bootstrap_tables", "level_score_table",
    "PathQParams", "calc_delay_cost", "calc_linkcap_cost", "calc_path_quality",
    "CongParams", "CongState", "monitor_update", "cong_signals", "calc_cong_cost",
    "SelectParams", "fused_cost", "select_egress", "ecmp_select", "fmix32",
    "FlowCache",
    "SwitchParams", "SwitchState", "make_switch", "monitor_tick",
    "route_batch", "gc_tick", "candidate_costs", "set_port_liveness",
]
