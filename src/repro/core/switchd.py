"""The full LCMP DCI-switch state machine (paper Fig. 2 runtime workflow).

Composes: bootstrap tables + path-quality table + congestion registers +
flow cache + two-stage selection into two entry points:

- ``monitor_tick``   : the lightweight monitor pass (refresh Q/T/D).
- ``route_batch``    : packet/flow arrival processing for a batch —
    established flows take the cached egress (stickiness), new flows (and
    flows whose egress died — lazy failover) run the full decision and are
    inserted into the cache.

The switch is a pure pytree; every transition is functional and
jittable. It is the switch-local composition used by the unit/property
tests and as the reference for the Pallas decision kernels. The netsim
``lax.scan`` (``repro.netsim.fluid``) does NOT run this object: it wires
the same underlying helpers directly — ``cong.monitor_update`` /
``calc_cong_cost`` feed the per-step ``hist_c`` score ring that ingress
decisions read with propagation delay, ``pathq.calc_path_quality`` is
re-run by the in-scan control-plane refresh (``fluid.ctrl_refresh``),
and ``select.select_egress`` makes the decision — while flow stickiness
lives in per-flow ``SimState`` instead of the bounded ``FlowCache``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import cong as congmod
from repro.core import flowcache as fc
from repro.core import select as selmod
from repro.core.cong import CongParams, CongState
from repro.core.pathq import PathQParams, calc_path_quality
from repro.core.select import SelectParams
from repro.core.tables import SwitchTables


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SwitchState:
    tables: SwitchTables
    c_path: jnp.ndarray          # (P,) int32 — installed per-candidate path quality
    cand_port: jnp.ndarray       # (P,) int32 — egress port of each candidate path
    cand_valid: jnp.ndarray      # (P,) bool  — candidate installed
    cong: CongState              # per-*port* congestion registers
    cache: fc.FlowCache
    port_alive: jnp.ndarray      # (num_ports,) bool


@dataclasses.dataclass(frozen=True)
class SwitchParams:
    pathq: PathQParams = PathQParams()
    cong: CongParams = CongParams()
    select: SelectParams = SelectParams()
    idle_timeout_us: int = 1_000_000  # flow-cache GC idle timeout


def make_switch(tables: SwitchTables, path_delay_us, path_cap_gbps, cand_port,
                num_ports: int, cache_capacity: int = 4096,
                params: SwitchParams = SwitchParams()) -> SwitchState:
    """Bootstrap: control plane installs tables + per-path C_path scores."""
    c_path = calc_path_quality(path_delay_us, path_cap_gbps,
                               tables.cap_thresh, params.pathq)
    cand_port = jnp.asarray(cand_port, jnp.int32)
    return SwitchState(
        tables=tables,
        c_path=c_path,
        cand_port=cand_port,
        cand_valid=jnp.ones(cand_port.shape, bool),
        cong=CongState.init(num_ports),
        cache=fc.FlowCache.init(cache_capacity),
        port_alive=jnp.ones((num_ports,), bool),
    )


def monitor_tick(sw: SwitchState, queue_bytes, now_us,
                 params: SwitchParams = SwitchParams()) -> SwitchState:
    """Monitor pass: sample per-port queues, update Q/T/D registers."""
    cong = congmod.monitor_update(sw.cong, queue_bytes, now_us,
                                  sw.tables, params.cong)
    return dataclasses.replace(sw, cong=cong)


def candidate_costs(sw: SwitchState, params: SwitchParams = SwitchParams()):
    """Per-candidate (C_path, C_cong, valid) triple (ports -> candidates)."""
    c_cong_port = congmod.calc_cong_cost(sw.cong, sw.tables, params.cong)
    c_cong = c_cong_port[sw.cand_port]
    valid = sw.cand_valid & sw.port_alive[sw.cand_port]
    return sw.c_path, c_cong, valid


def route_batch(sw: SwitchState, flow_ids: jnp.ndarray, now_us,
                params: SwitchParams = SwitchParams()):
    """Process a batch of packet arrivals; returns (sw', candidate_idx, is_new).

    Established flows (cache hit + live egress) keep their path; everyone
    else runs the full LCMP decision. The returned index is into the
    switch's candidate-path table.
    """
    flow_ids = jnp.asarray(flow_ids).astype(jnp.uint32)
    # candidate -> port liveness feeds the lazy-failover lookup: the cache
    # stores *candidate* indices, so a candidate is "alive" iff its port is.
    cand_alive = sw.port_alive[sw.cand_port] & sw.cand_valid
    hit, cached_idx, slot = fc.lookup(sw.cache, flow_ids, cand_alive)
    cache = fc.refresh(sw.cache, slot, hit, now_us)

    c_path, c_cong, valid = candidate_costs(sw, params)
    fresh_idx, _ = selmod.select_egress(flow_ids, c_path, c_cong, valid,
                                        params.select)
    choice = jnp.where(hit, cached_idx, fresh_idx)
    cache = fc.insert(cache, flow_ids, fresh_idx, now_us, ~hit)
    return dataclasses.replace(sw, cache=cache), choice, ~hit


def gc_tick(sw: SwitchState, now_us,
            params: SwitchParams = SwitchParams()) -> SwitchState:
    return dataclasses.replace(
        sw, cache=fc.garbage_collect(sw.cache, now_us, params.idle_timeout_us))


def set_port_liveness(sw: SwitchState, port_alive) -> SwitchState:
    """Data-plane port liveness update (fast-failover input)."""
    return dataclasses.replace(sw, port_alive=jnp.asarray(port_alive, bool))
