"""Bounded flow cache: per-flow path stickiness + GC + lazy fast-failover.

Paper §3.1.2 (4)/(5) and §3.4:
- entry = (flowId, outDevIdx, lastSeen); only the *first* packet of a flow
  runs the full cost computation, later packets hit the cache and refresh
  lastSeen (in-order delivery for RDMA).
- periodic GC evicts entries idle past a timeout, keeping the cache bounded.
- fast-failover is *lazy*: a hit whose egress port is dead is treated as a
  miss — the entry is overwritten by a fresh decision on the packet path,
  with zero control-plane involvement (μs-scale recovery).

Implementation: direct-mapped hash cache (slot = fmix32(flow) % capacity)
as a struct-of-arrays — the functional-JAX equivalent of switch register
files. Collisions simply overwrite (bounded state, like real hardware).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.select import fmix32


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlowCache:
    flow_id: jnp.ndarray    # (C,) uint32 — key
    out_idx: jnp.ndarray    # (C,) int32  — chosen egress/candidate index
    last_seen: jnp.ndarray  # (C,) int32  — microseconds
    valid: jnp.ndarray      # (C,) bool

    @classmethod
    def init(cls, capacity: int) -> "FlowCache":
        return cls(
            flow_id=jnp.zeros((capacity,), jnp.uint32),
            out_idx=jnp.full((capacity,), -1, jnp.int32),
            last_seen=jnp.zeros((capacity,), jnp.int32),
            valid=jnp.zeros((capacity,), bool),
        )

    @property
    def capacity(self) -> int:
        return self.flow_id.shape[0]


def _slot(cache: FlowCache, flow_ids: jnp.ndarray) -> jnp.ndarray:
    return (fmix32(flow_ids) % jnp.uint32(cache.capacity)).astype(jnp.int32)


def lookup(cache: FlowCache, flow_ids: jnp.ndarray, port_alive: jnp.ndarray):
    """Vectorized lookup. Returns (hit, out_idx, slot).

    A hit requires: slot valid, key match, and the recorded egress still
    alive — a dead egress makes it a miss (lazy failover re-decision).
    """
    flow_ids = jnp.asarray(flow_ids).astype(jnp.uint32)
    slot = _slot(cache, flow_ids)
    key_ok = cache.valid[slot] & (cache.flow_id[slot] == flow_ids)
    out = cache.out_idx[slot]
    alive = jnp.asarray(port_alive, bool)[jnp.maximum(out, 0)]
    hit = key_ok & alive
    return hit, jnp.where(hit, out, -1), slot


def refresh(cache: FlowCache, slot: jnp.ndarray, hit: jnp.ndarray,
            now_us) -> FlowCache:
    """Refresh lastSeen for hits (established-flow packet arrival)."""
    ls = cache.last_seen.at[slot].set(
        jnp.where(hit, jnp.asarray(now_us, jnp.int32), cache.last_seen[slot]))
    return dataclasses.replace(cache, last_seen=ls)


def insert(cache: FlowCache, flow_ids: jnp.ndarray, out_idx: jnp.ndarray,
           now_us, do_insert: jnp.ndarray) -> FlowCache:
    """Record fresh decisions (first packet of each flow). Vectorized;
    on intra-batch slot collisions the last writer wins (hardware-like)."""
    flow_ids = jnp.asarray(flow_ids).astype(jnp.uint32)
    slot = _slot(cache, flow_ids)
    do = jnp.asarray(do_insert, bool) & (out_idx >= 0)
    # guard: masked-out lanes write to their own slot's current value
    cur_id, cur_out = cache.flow_id[slot], cache.out_idx[slot]
    cur_seen, cur_valid = cache.last_seen[slot], cache.valid[slot]
    return FlowCache(
        flow_id=cache.flow_id.at[slot].set(jnp.where(do, flow_ids, cur_id)),
        out_idx=cache.out_idx.at[slot].set(jnp.where(do, out_idx, cur_out)),
        last_seen=cache.last_seen.at[slot].set(
            jnp.where(do, jnp.asarray(now_us, jnp.int32), cur_seen)),
        valid=cache.valid.at[slot].set(cur_valid | do),
    )


def garbage_collect(cache: FlowCache, now_us, idle_timeout_us) -> FlowCache:
    """Periodic GC: evict entries idle past the timeout (paper workflow 4)."""
    fresh = (jnp.asarray(now_us, jnp.int32) - cache.last_seen) <= jnp.asarray(
        idle_timeout_us, jnp.int32)
    return dataclasses.replace(cache, valid=cache.valid & fresh)


def invalidate_ports(cache: FlowCache, port_alive: jnp.ndarray) -> FlowCache:
    """Eager variant of failover (control-plane batch invalidation). The
    production path is the *lazy* one inside ``lookup``; this exists for
    tests and for operators who prefer eager sweeps."""
    alive = jnp.asarray(port_alive, bool)[jnp.maximum(cache.out_idx, 0)]
    return dataclasses.replace(cache, valid=cache.valid & alive)
