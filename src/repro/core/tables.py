"""Control-plane bootstrap tables (paper §3.1.2, Fig. 3).

The control plane installs a small set of integer vectors on each DCI
switch at bootstrap; the data plane then only does lookups + integer
comparisons. All tables are int32 jnp arrays so they can live in
switch-register-like JAX state and be gathered at line rate.

Units: queue depths are measured in **cells of 1 KiB** — real switch
ASICs count buffer cells (not bytes) precisely so the 32-bit registers
the paper budgets (§4) can cover multi-GB long-haul buffers. 6 GB = ~5.9M
cells, comfortably int32.

Tables
------
- capacity-class thresholds  : N increasing Gbps boundaries -> class index
- queue thresholds (qThresh) : per-port cell boundaries -> queue level Q
- levelScore                 : linear level-index -> 0..255 score map
- trend normalization        : per link-rate bucket, cells/interval
                               boundaries -> trend level T
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

SCORE_MAX = 255          # all scores are 8-bit quantities (paper: 0-255)
CELL_BYTES = 1024        # queue accounting granularity (1 cell = 1 KiB)


def bytes_to_cells(b) -> jnp.ndarray:
    """Bytes -> int32 cells (floor). Accepts python ints or float arrays."""
    if isinstance(b, (int, float)):
        return jnp.int32(int(b) // CELL_BYTES)
    return (jnp.asarray(b, jnp.float32) / CELL_BYTES).astype(jnp.int32)


def level_score_table(num_levels: int) -> jnp.ndarray:
    """Precomputed linear mapping from level index to a 0-255 score.

    Paper §3.1.2: "A linear mapping from level index to a 0-255 score is
    precomputed. This avoids per-packet floating computation."
    """
    if num_levels < 2:
        return jnp.zeros((max(num_levels, 1),), jnp.int32)
    idx = jnp.arange(num_levels, dtype=jnp.int32)
    return (idx * SCORE_MAX) // (num_levels - 1)


def capacity_class_thresholds(max_capacity_gbps: int, num_classes: int = 10) -> jnp.ndarray:
    """Increasing link-capacity thresholds (Gbps), proportional to a
    configured maximum capacity (paper: "each class boundary is
    proportional to a configured link capacity")."""
    cls = jnp.arange(1, num_classes, dtype=jnp.int32)
    return (cls * max_capacity_gbps) // num_classes  # (num_classes-1,) boundaries


def queue_thresholds(buffer_bytes: int, num_levels: int = 16) -> jnp.ndarray:
    """Per-port egress-buffer cell boundaries mapping queue cells -> level.

    Exponential (doubling) ladder: the top boundary is the full buffer and
    each level below halves it. Long-haul buffers are BDP-sized (6 GB,
    paper §6.2) so a *linear* split would be blind until hundreds of MB
    queue up; the doubling ladder is fine-grained exactly where "imminent
    queue buildup" (§2.3-C2) lives, while still covering the whole buffer.
    Integer-only.
    """
    buffer_cells = max(buffer_bytes // CELL_BYTES, num_levels)
    th = [max(buffer_cells >> (num_levels - 1 - i), 1)
          for i in range(1, num_levels)]
    return jnp.asarray(th, jnp.int32)  # (num_levels-1,) increasing


def trend_thresholds(link_rate_gbps: int, sample_interval_us: int,
                     num_levels: int = 16) -> jnp.ndarray:
    """Per-rate-bucket trend normalization vector (paper §3.1.2).

    The raw trend accumulator is in cells-per-sample-interval units. A
    trend equal to a large fraction of what the link can move in one
    interval is "fast growth"; boundaries ramp linearly to 50% of the
    per-interval line-rate cells.
    """
    cells_per_interval = ((link_rate_gbps * 10**9 // 8) * sample_interval_us
                          // 1_000_000) // CELL_BYTES
    th = [(i * (cells_per_interval // 2)) // (num_levels - 1)
          for i in range(1, num_levels)]
    return jnp.asarray(th, jnp.int32)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SwitchTables:
    """Everything the control plane installs at bootstrap (Fig. 3)."""
    cap_thresh: jnp.ndarray      # (num_classes-1,) int32 Gbps boundaries
    level_score: jnp.ndarray     # (num_levels,)    int32 0..255
    q_thresh: jnp.ndarray        # (num_levels-1,)  int32 cells
    trend_thresh: jnp.ndarray    # (num_ports, num_levels-1) int32 per-port
                                 #   (expanded from per-rate-bucket vectors)
    high_water_level: jnp.ndarray  # () int32 — D counter arms above this Q level

    @property
    def num_levels(self) -> int:
        return self.level_score.shape[0]


def bootstrap_tables(port_rates_gbps: Sequence[int], *,
                     buffer_bytes: int = 6 * 10**9,
                     sample_interval_us: int = 100,
                     num_classes: int = 10,
                     num_levels: int = 16,
                     max_capacity_gbps: int = 400,
                     high_water_frac: float = 0.625) -> SwitchTables:
    """Build the full bootstrap table set for one DCI switch.

    ``port_rates_gbps`` lists the configured rate of each egress port; the
    per-rate trend tables are materialized per port (the paper stores one
    per coarse rate bucket and creates missing buckets on demand —
    expanding per port is the dense-array equivalent).
    """
    rates = list(port_rates_gbps)
    trend = jnp.stack([trend_thresholds(r, sample_interval_us, num_levels) for r in rates])
    return SwitchTables(
        cap_thresh=capacity_class_thresholds(max_capacity_gbps, num_classes),
        level_score=level_score_table(num_levels),
        q_thresh=queue_thresholds(buffer_bytes, num_levels),
        trend_thresh=trend,
        high_water_level=jnp.asarray(int(high_water_frac * (num_levels - 1)), jnp.int32),
    )
