"""Compact control-plane path-quality representation (paper §3.2).

``C_path(p) = min((w_dl * delayScore(p) + w_lc * linkCapScore(p)) >> S_path, 255)``

Both mapping functions are deliberately integer-only:

- Alg. 1 ``CalcDelayCost``      : saturating, shift-based map of one-way
  propagation delay (microseconds) to 0..255.
- Alg. 2 ``CalcLinkCapCost``    : capacity-class lookup against the
  preinstalled threshold vector; *higher* capacity maps to a *lower* cost
  class so the fused metric prefers fat links.

All functions broadcast over arbitrary leading shapes (paths, flows x
paths, ...), so the control plane can score the whole path table in one
call.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tables import SCORE_MAX, level_score_table


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PathQParams:
    """Integer weights/shifts for Eq. (2). Defaults = paper §7.3 best."""
    w_dl: int = dataclasses.field(default=3, metadata=dict(static=True))
    w_lc: int = dataclasses.field(default=1, metadata=dict(static=True))
    # saturating shift for the delay map: delayScore = min(us >> d_shift, 255).
    # d_shift=8 saturates at 255*256us ~= 65.3ms (paper: "e.g. 32, 64 ms").
    d_shift: int = dataclasses.field(default=8, metadata=dict(static=True))

    @property
    def s_path(self) -> int:
        # right-shift normalization keeping the fused score inside 8 bits
        total = self.w_dl + self.w_lc
        return max(total - 1, 0).bit_length()


def calc_delay_cost(delay_us: jnp.ndarray, params: PathQParams = PathQParams()) -> jnp.ndarray:
    """Alg. 1: saturating shift-based delay -> 0..255 score."""
    d = jnp.asarray(delay_us, jnp.int32)
    return jnp.minimum(jnp.right_shift(d, params.d_shift), SCORE_MAX).astype(jnp.int32)


def calc_linkcap_cost(cap_gbps: jnp.ndarray, cap_thresh: jnp.ndarray) -> jnp.ndarray:
    """Alg. 2: link capacity-class lookup -> 0..255 score (fat link = low cost).

    ``cap_thresh`` is the (num_classes-1,) increasing boundary vector; the
    class index is the count of boundaries <= capacity, and the score is
    the *inverted* linear level score so the highest class costs 0.
    """
    cap = jnp.asarray(cap_gbps, jnp.int32)
    num_classes = cap_thresh.shape[0] + 1
    cls = jnp.searchsorted(cap_thresh, cap, side="right").astype(jnp.int32)
    score_of_class = level_score_table(num_classes)  # 0..255 increasing
    inv = score_of_class[num_classes - 1 - cls]      # invert: big cap -> small cost
    return inv.astype(jnp.int32)


def calc_path_quality(delay_us: jnp.ndarray, cap_gbps: jnp.ndarray,
                      cap_thresh: jnp.ndarray,
                      params: PathQParams = PathQParams()) -> jnp.ndarray:
    """Eq. (2): fused, normalized C_path in [0, 255]."""
    ds = calc_delay_cost(delay_us, params)
    lc = calc_linkcap_cost(cap_gbps, cap_thresh)
    fused = params.w_dl * ds + params.w_lc * lc
    return jnp.minimum(jnp.right_shift(fused, params.s_path), SCORE_MAX).astype(jnp.int32)


def path_bottleneck_stats(link_delay_us: jnp.ndarray, link_cap_gbps: jnp.ndarray,
                          path_links: jnp.ndarray, path_len: jnp.ndarray):
    """Reduce per-link attributes to per-path (delay = sum, cap = min).

    ``path_links``: (P, H) int32 link indices padded with -1;
    ``path_len``  : (P,) number of valid hops.
    Control-plane-side helper for installing (and periodically
    re-installing) the C_path table — the netsim control-plane refresh
    (``fluid.ctrl_refresh``) calls it each tick with *effective*
    capacities, so it must accept capacities already scaled by degrade
    factors/liveness (0 for a dead link).
    """
    H = path_links.shape[-1]
    hop_valid = jnp.arange(H)[None, :] < path_len[:, None]
    safe = jnp.maximum(path_links, 0)
    d = jnp.where(hop_valid, link_delay_us[safe], 0).sum(-1)
    c = jnp.where(hop_valid, link_cap_gbps[safe], jnp.iinfo(jnp.int32).max).min(-1)
    return d.astype(jnp.int32), c.astype(jnp.int32)
