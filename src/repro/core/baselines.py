"""Routing baselines the paper compares against (§6.1): ECMP, WCMP, UCMP,
a RedTE-like coarse-timescale distributed-TE policy, and a FatPaths-style
layered scheme (flowlet re-hashing is supplied by the engine's
re-decision tick, see ``netsim.engine.redecide_tick``).

Each baseline shares the signature
    ``choose(flow_ids, path_delay_us, path_cap_gbps, valid, **state) -> idx``
so the simulator can swap policies with one config string.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.select import ecmp_select, fmix32

_BIG = jnp.int32(1 << 30)


def ecmp(flow_ids, path_delay_us, path_cap_gbps, valid):
    """Oblivious equal-cost hashing over all candidates (RFC 2992)."""
    del path_delay_us, path_cap_gbps
    return ecmp_select(flow_ids, valid)


def _weighted_hash(flow_ids, weights, valid):
    """Pick candidate i with probability weight_i / sum(weights) using a
    deterministic per-flow hash (integer cumulative-threshold trick)."""
    w = jnp.where(valid, jnp.maximum(jnp.asarray(weights, jnp.int32), 1), 0)
    F = jnp.asarray(flow_ids).shape[0]
    w = jnp.broadcast_to(w, (F,) + w.shape[-1:])
    cum = jnp.cumsum(w, axis=-1)
    total = cum[:, -1]
    h = ((fmix32(flow_ids) >> 1).astype(jnp.int32) % jnp.maximum(total, 1))
    choice = (cum <= h[:, None]).sum(-1).astype(jnp.int32)
    return jnp.where(total > 0, choice, -1)


def wcmp(flow_ids, path_delay_us, path_cap_gbps, valid):
    """WCMP: static weights proportional to provisioned capacity."""
    del path_delay_us
    return _weighted_hash(flow_ids, path_cap_gbps, valid)


def ucmp(flow_ids, path_delay_us, path_cap_gbps, valid,
         wait_cost_us: int = 0):
    """UCMP-style uniform cost (SIGCOMM'24, reconfigurable DCNs): unify a
    circuit-wait term with transmission capacity into one cost and take the
    cheapest. In a conventional WAN the wait term is ~0, so the cost
    degenerates to 1/capacity — exactly the capacity-centric bias Fig. 1
    demonstrates (concentrates on fat-but-slow links, ignores delay).
    Ties are hashed for determinism."""
    del path_delay_us
    cap = jnp.maximum(jnp.asarray(path_cap_gbps, jnp.int32), 1)
    cost = wait_cost_us + (jnp.int32(1_000_000) // cap)   # integer 1/cap scale
    cost = jnp.where(jnp.asarray(valid, bool), cost, _BIG)
    F = jnp.asarray(flow_ids).shape[0]
    cost = jnp.broadcast_to(cost, (F,) + cost.shape[-1:])
    P = cost.shape[-1]
    # deterministic tie-break by per-flow hashed rotation
    rot = (fmix32(flow_ids) % jnp.uint32(P)).astype(jnp.int32)
    idx = (jnp.arange(P, dtype=jnp.int32)[None, :] + rot[:, None]) % P
    rot_cost = jnp.take_along_axis(cost, idx, axis=-1)
    best = jnp.argmin(rot_cost, axis=-1).astype(jnp.int32)
    choice = jnp.take_along_axis(idx, best[:, None], axis=-1)[:, 0]
    any_valid = jnp.asarray(valid, bool).sum(-1) > 0
    return jnp.where(any_valid, choice, -1)


def fatpaths(flow_ids, path_len, valid, c_cong, cong_thresh: int = 230):
    """FatPaths-style layered routing (arXiv 1906.10885, adapted to the
    WAN candidate-set setting): candidates are grouped into layers by
    hop-count stretch over the pair's shortest valid route; a flow(let)
    hashes uniformly inside the minimal-stretch layer and spills to the
    *full* valid set only when every minimal-layer candidate looks
    congested from the ingress (``c_cong >= cong_thresh`` — the same
    "all highly congested" bar LCMP's fallback uses, so neither scheme
    gets a private threshold). The per-flowlet re-hash (salted flow ids
    from the re-decision tick) supplies the adaptivity; the layering
    itself stays delay- and cost-oblivious, which is exactly the gap the
    LCMP comparison probes on long-haul topologies.

    ``path_len``: (F, P) or (P,) int hop counts per candidate slot.
    """
    valid = jnp.asarray(valid, bool)
    F = jnp.asarray(flow_ids).shape[0]
    plen = jnp.asarray(path_len, jnp.int32)
    plen = jnp.broadcast_to(plen, (F,) + plen.shape[-1:])
    valid = jnp.broadcast_to(valid, plen.shape)
    cong = jnp.broadcast_to(jnp.asarray(c_cong, jnp.int32), plen.shape)
    minlen = jnp.where(valid, plen, _BIG).min(-1)               # (F,)
    layer0 = valid & (plen == minlen[:, None])
    spill = jnp.where(layer0, cong, _BIG).min(-1) >= cong_thresh
    active_set = jnp.where(spill[:, None], valid, layer0)
    return ecmp_select(flow_ids, active_set)


def matchrdma(flow_ids, span_avail, valid):
    """MatchRDMA-style segmented per-span rate matching (arXiv
    2604.23932, adapted to the WAN candidate-set setting): long-haul
    RDMA throughput is set by the *tightest OTN span* en route, so each
    candidate is scored by its matched rate — the minimum over its spans
    of effective capacity x headroom — and the flow takes the candidate
    whose bottleneck span currently admits the most. Degradation-aware
    (effective capacities) and utilization-aware (headroom), but
    delay-oblivious: on delay-dominated long hauls it keeps matching
    toward fat-but-slow spans, exactly the capacity-centric gap the LCMP
    comparison probes.

    ``span_avail``: (F, P) or (P,) int32 matched-rate score per candidate
    (min over spans, computed by the engine from its live link state).
    Ties are hashed for determinism (same rotation trick as ``ucmp``).
    """
    avail = jnp.asarray(span_avail, jnp.int32)
    cost = jnp.where(jnp.asarray(valid, bool), -avail, _BIG)
    F = jnp.asarray(flow_ids).shape[0]
    cost = jnp.broadcast_to(cost, (F,) + cost.shape[-1:])
    P = cost.shape[-1]
    rot = (fmix32(flow_ids) % jnp.uint32(P)).astype(jnp.int32)
    idx = (jnp.arange(P, dtype=jnp.int32)[None, :] + rot[:, None]) % P
    rot_cost = jnp.take_along_axis(cost, idx, axis=-1)
    best = jnp.argmin(rot_cost, axis=-1).astype(jnp.int32)
    choice = jnp.take_along_axis(idx, best[:, None], axis=-1)[:, 0]
    any_valid = jnp.asarray(valid, bool).sum(-1) > 0
    return jnp.where(any_valid, choice, -1)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class RedTEState:
    """Coarse-timescale split ratios, re-optimized every ``period_us``.

    RedTE (SIGCOMM'24) learns per-router split ratios with a ~100 ms
    control loop; the paper observes that at RDMA micro-burst timescales
    it degenerates toward static hashing. We model the control loop
    faithfully at the *timescale* level: every period the ratios move
    toward inverse recent-utilization (the optimizer's fixed point),
    between updates the ratios are static weights for hashing."""
    weights: jnp.ndarray       # (P,) int32 current split weights
    last_update_us: jnp.ndarray  # () int32

    @classmethod
    def init(cls, num_paths: int) -> "RedTEState":
        return cls(weights=jnp.ones((num_paths,), jnp.int32),
                   last_update_us=jnp.asarray(-(1 << 30), jnp.int32))


def redte_update(state: RedTEState, now_us, path_util_q8: jnp.ndarray,
                 period_us: int = 100_000) -> RedTEState:
    """Periodic re-optimization: weight_i ∝ headroom = (256 - util_q8)."""
    due = (jnp.asarray(now_us, jnp.int32) - state.last_update_us) >= period_us
    headroom = jnp.maximum(256 - jnp.asarray(path_util_q8, jnp.int32), 1)
    new_w = jnp.where(due, headroom, state.weights)
    new_t = jnp.where(due, jnp.asarray(now_us, jnp.int32), state.last_update_us)
    return RedTEState(weights=new_w, last_update_us=new_t)


def redte(flow_ids, path_delay_us, path_cap_gbps, valid, state: RedTEState):
    del path_delay_us, path_cap_gbps
    return _weighted_hash(flow_ids, state.weights, valid)
