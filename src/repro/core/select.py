"""Fused cost + diversity-preserving selection (paper §3.1.1 Eq. 1, §3.4).

For a batch of new flows (the simultaneous-arrival case is literally the
leading axis here) and per-flow candidate sets:

1. ``C(p) = alpha*C_path(p) + beta*C_cong(p)``            (Eq. 1)
2. sort candidates by fused cost (m <= 8, cheap),
3. drop the high-cost suffix — keep the lower half,
4. hash-ECMP *inside* the reduced set (per-flow fmix32 hash so a burst of
   flows decorrelates even within one vectorized call),
5. fallback: if every candidate is highly congested, take argmin cost
   ("pointless randomization among uniformly bad choices").

Invalid candidate slots (padded sets) carry +inf-like sentinel costs and
are never selected.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.tables import SCORE_MAX

_COST_INVALID = jnp.int32(1 << 24)  # sentinel far above any fusable cost


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SelectParams:
    """Defaults = paper §5/§7: (alpha, beta) = (3, 1); keep lower 50%."""
    alpha: int = dataclasses.field(default=3, metadata=dict(static=True))
    beta: int = dataclasses.field(default=1, metadata=dict(static=True))
    keep_num: int = dataclasses.field(default=2, metadata=dict(static=True))   # keep ceil(m/keep_num): 2 -> lower half
    cong_fallback: int = dataclasses.field(default=230, metadata=dict(static=True))  # "all highly congested" bar


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """MurmurHash3 finalizer — cheap avalanche for flow IDs (uint32)."""
    x = jnp.asarray(x).astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def fused_cost(c_path: jnp.ndarray, c_cong: jnp.ndarray,
               params: SelectParams = SelectParams()) -> jnp.ndarray:
    """Eq. (1) over broadcastable int32 score arrays."""
    return (params.alpha * jnp.asarray(c_path, jnp.int32)
            + params.beta * jnp.asarray(c_cong, jnp.int32))


def select_egress(flow_ids: jnp.ndarray, c_path: jnp.ndarray, c_cong: jnp.ndarray,
                  valid: jnp.ndarray, params: SelectParams = SelectParams(),
                  weights: jnp.ndarray | None = None):
    """Two-stage diversity-preserving selection.

    Args:
      flow_ids: (F,) uint32/int32 flow identifiers (five-tuple hash).
      c_path:   (F, P) or (P,) per-candidate path-quality scores.
      c_cong:   (F, P) or (P,) per-candidate congestion scores.
      valid:    (F, P) or (P,) bool — candidate slot is a real path.
      weights:  optional (F, P) or (P,) int — when given, the stage-2 hash
                inside the kept set is *weighted* by these (e.g. link
                capacities) instead of uniform. This is the BEYOND-PAPER
                "LCMP-W" variant (see EXPERIMENTS §beyond-paper): uniform
                hashing sends 1/keep of the *bytes* to the thinnest kept
                path, which saturates it at high load; capacity weighting
                equalizes kept-set utilization instead.
    Returns:
      choice:   (F,) int32 index into the candidate axis.
      cost:     (F, P) int32 fused costs (invalid slots = sentinel).
    """
    flow_ids = jnp.asarray(flow_ids)
    F = flow_ids.shape[0]
    cost = fused_cost(c_path, c_cong, params)
    cost = jnp.broadcast_to(cost, (F,) + cost.shape[-1:])
    valid = jnp.broadcast_to(jnp.asarray(valid, bool), cost.shape)
    c_cong_b = jnp.broadcast_to(jnp.asarray(c_cong, jnp.int32), cost.shape)
    P = cost.shape[-1]

    cost = jnp.where(valid, cost, _COST_INVALID)

    # stage 1: rank candidates (sort keys carry the original index in the
    # low bits so ties break deterministically, like a stable ASIC sort)
    key = cost * P + jnp.arange(P, dtype=jnp.int32)[None, :]
    order = jnp.argsort(key, axis=-1)                      # (F, P) ascending cost

    num_valid = valid.sum(-1).astype(jnp.int32)            # (F,)
    keep = jnp.maximum((num_valid + params.keep_num - 1) // params.keep_num, 1)

    # stage 2: hash-ECMP inside the reduced (lowest-cost) prefix
    h = fmix32(flow_ids)
    if weights is None:
        pick_rank = (h % keep.astype(jnp.uint32)).astype(jnp.int32)  # (F,)
    else:
        w = jnp.broadcast_to(jnp.asarray(weights, jnp.int32), cost.shape)
        w_sorted = jnp.take_along_axis(w, order, axis=-1)            # by rank
        in_keep = jnp.arange(P, dtype=jnp.int32)[None, :] < keep[:, None]
        w_kept = jnp.where(in_keep, jnp.maximum(w_sorted, 1), 0)
        cum = jnp.cumsum(w_kept, axis=-1)
        hv = ((h >> 1).astype(jnp.int32) % jnp.maximum(cum[:, -1], 1))
        pick_rank = (cum <= hv[:, None]).sum(-1).astype(jnp.int32)
    hashed_choice = jnp.take_along_axis(order, pick_rank[:, None], axis=-1)[:, 0]

    # fallback: all candidates highly congested -> pure argmin of fused cost
    min_cong = jnp.where(valid, c_cong_b, SCORE_MAX + 1).min(-1)
    all_bad = min_cong >= params.cong_fallback
    argmin_choice = order[:, 0]
    choice = jnp.where(all_bad, argmin_choice, hashed_choice)

    # degenerate: no valid candidate at all -> report -1
    choice = jnp.where(num_valid > 0, choice, -1)
    return choice.astype(jnp.int32), cost


def ecmp_select(flow_ids: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Plain ECMP: uniform hash over *all* valid candidates (baseline)."""
    valid = jnp.asarray(valid, bool)
    F = jnp.asarray(flow_ids).shape[0]
    valid = jnp.broadcast_to(valid, (F,) + valid.shape[-1:])
    P = valid.shape[-1]
    num_valid = valid.sum(-1).astype(jnp.uint32)
    # rank -> index map: stable order of valid slots
    order = jnp.argsort(jnp.where(valid, 0, 1) * P + jnp.arange(P)[None, :], axis=-1)
    rank = (fmix32(flow_ids) % jnp.maximum(num_valid, 1)).astype(jnp.int32)
    choice = jnp.take_along_axis(order, rank[:, None], axis=-1)[:, 0]
    return jnp.where(num_valid > 0, choice, -1).astype(jnp.int32)
