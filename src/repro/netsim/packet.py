"""Slotted packet-level simulation engine (the NS-3 analogue of paper
§6, Figs. 7-9) — the high-fidelity backend of the multi-engine core.

Where the fluid engine (``repro.netsim.fluid``) abstracts links as
max-min rate dividers with analytically integrated queues, this engine
moves *bytes of whole MTU packets* hop by hop through per-flow FIFO
queues, as one fully-batched jitted ``lax.scan`` over time slots:

- **windowed, paced sources**: each flow injects whole ``mtu_bytes``
  packets paced by its CC rate (a per-flow credit accumulator carries
  fractional packets across slots), bounded by the rate-BDP window
  ``rate x RTT`` — in-flight (queued) bytes never exceed the window, so
  the CC laws govern both rate *and* burst size. The final sub-MTU runt
  packet is injected exactly.
- **store-and-forward hop queues**: ``fq[f, h]`` holds flow ``f``'s
  bytes queued at the egress of its ``h``-th hop link. Each slot serves
  hops in path order under per-link byte budgets (``cap x dt``, shared
  across all hop positions a link appears in), so a packet can cut
  through an idle path within one slot but never exceeds any link's
  service rate. Per-flow service within a slot splits a link's budget
  proportionally to queued bytes (byte-wise FIFO fairness).
- **PFC pause/resume (lossless RDMA)**: per-link XOFF/XON hysteresis on
  instantaneous queue depth (``pfc_xoff_frac``/``pfc_xon_frac`` of the
  scaled buffer). The pause state reaches the *upstream* transmitter one
  backward link-propagation delay late (the ``hist_pause`` ring), so a
  paused long-haul queue keeps absorbing in-flight bytes for a full
  one-way delay — the headroom problem 6 GB long-haul buffers exist
  for. Buffer space itself is a hard bound (byte-conserving acceptance
  factors), so nothing is ever dropped.
- **ECN at the switch, delayed to the source**: per-slot queue depths
  land in the shared ``hist_q`` ring; the shared ``engine._cc_update``
  laws read them one RTT late and mark RED-style between ``Kmin`` and
  ``Kmax = ecn_kmax_factor x Kmin`` — the same signal chain as the
  fluid engine, fed by packet-granular queue dynamics.
- **identical control/signal/routing planes**: the ``core.cong``
  register pipeline (``engine.monitor_tick`` -> ``hist_c``), the
  propagation-delayed ``path_cong_view``, the periodic ``C_path``
  re-install (``engine.ctrl_tick``), arrival-time routing through
  ``select.select_egress``/baselines (``engine._route_arrivals``), flow
  stickiness, and lazy failover are the *same functions* the fluid
  engine runs — the engines differ only in data-plane dynamics. The
  mid-flow re-decision plane (``engine.redecide_tick``) is shared too,
  but its *eligibility* is this engine's own: genuine flowlet idle gaps
  (``last_tx`` + drained hop queues for >= ``flowlet_gap_us``), where
  the fluid engine can only offer a timer epoch.

FCT is measured by actual delivery: a flow completes when its last byte
leaves its last hop queue; propagation (applied analytically, exactly as
the fluid engine does) is added once. Queueing delay is therefore
*experienced*, not estimated — no ``extra_wait`` correction terms.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.netsim import engine, sanitize
from repro.netsim.engine import (HIST, SimArrays, SimConfig, SimState,
                                 _cc_update, _reroute_dead, _route_arrivals,
                                 ctrl_tick, monitor_tick, redecide_tick,
                                 redte_tick, wants_redecide)
from repro.netsim.paths import PathTable
from repro.traffic.gen import FlowSet

name = "packet"


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketState(SimState):
    """``SimState`` plus the packet data plane. In-flight bytes of flow
    ``f`` are exactly ``fq[f].sum()`` — injected but not yet delivered."""
    fq: jnp.ndarray          # (F, H) f32 bytes queued at each hop egress
    credit: jnp.ndarray      # (F,) f32 pacing credit (fractional packets)
    delivered: jnp.ndarray   # (F,) f32 bytes delivered at destination
    last_tx: jnp.ndarray     # (F,) i32 last slot the flow had bytes in
                             # flight (flowlet idle-gap detection; only
                             # maintained when the re-decision plane is on)
    pfc_pause: jnp.ndarray   # (L,) bool current XOFF state
    hist_pause: jnp.ndarray  # (L, HIST) bool pause ring (upstream reads
                             # it one backward link propagation late)


def build(table: PathTable, flows: FlowSet, cfg: SimConfig):
    """Shared ``engine.build`` plus zero-initialized packet state."""
    arr, base = engine.build(table, flows, cfg)
    F = base.flow_path.shape[0]
    L = base.q_bytes.shape[0]
    H = arr.path_links.shape[1]
    state = PacketState(
        **{f.name: getattr(base, f.name)
           for f in dataclasses.fields(SimState)},
        fq=jnp.zeros((F, H), jnp.float32),
        credit=jnp.zeros((F,), jnp.float32),
        delivered=jnp.zeros((F,), jnp.float32),
        last_tx=jnp.full((F,), 1 << 20, jnp.int32),  # sentinel: never sent
                                                     # (t - last_tx < 0 so a
                                                     # routed-but-quiet flow
                                                     # is not flowlet-eligible)
        pfc_pause=jnp.zeros((L,), bool),
        hist_pause=jnp.zeros((L, HIST), bool),
    )
    return arr, state


def _reroute_dead_packet(t, st: PacketState, ar: SimArrays,
                         cfg: SimConfig) -> PacketState:
    """Lazy failover with packet-queue cleanup: the shared reroute
    re-decides paths/CC; bytes stranded in the dead path's queues are
    treated as lost-and-retransmitted (go-back-N) — returned to
    ``remaining`` so the flow re-sends them on the new path."""
    old_path, old_active = st.flow_path, st.active
    st2 = _reroute_dead(t, st, ar, cfg)
    moved = old_active & ((st2.flow_path != old_path) | ~st2.active)
    stranded = st.fq.sum(-1)
    return dataclasses.replace(
        st2,
        remaining=jnp.where(moved, st2.remaining + stranded, st2.remaining),
        fq=jnp.where(moved[:, None], 0.0, st.fq),
        credit=jnp.where(moved, 0.0, st.credit))


def make_step(ar: SimArrays, cfg: SimConfig):
    L = ar.link_cap.shape[0]
    H = ar.path_links.shape[1]
    dt = float(cfg.dt_us)
    mtu = float(cfg.mtu_bytes)
    buf = float(cfg.buffer_bytes * cfg.cap_scale)
    xoff = cfg.pfc_xoff_frac * buf
    xon = cfg.pfc_xon_frac * buf
    checks_on = sanitize.enabled(cfg)

    def seg(vals, idx):
        return jax.ops.segment_sum(vals, idx, num_segments=L)

    def step(st: PacketState, t):
        # 0) failure injection + lazy fast-failover (shared semantics,
        # plus dead-queue cleanup — see _reroute_dead_packet)
        if cfg.has_failures:
            st = dataclasses.replace(st, link_alive=t < ar.link_fail_step)
            is_trip = (ar.link_fail_step == t).any()
            st = jax.lax.cond(is_trip,
                              lambda s: _reroute_dead_packet(t, s, ar, cfg),
                              lambda s: s, st)

        # 1) switch monitor tick + control-plane refresh (shared)
        st = monitor_tick(t, st, ar, cfg)
        st = ctrl_tick(t, st, ar, cfg)

        # 2) arrivals + routing decisions (shared herd batch)
        st = _route_arrivals(t, st, ar, cfg)

        # 2b) flowlet re-hash (FatPaths semantics): a flow whose hop
        # queues fully drained >= flowlet_gap_us ago may re-decide — the
        # inter-flowlet idle gap guarantees no packets of the previous
        # flowlet are still in flight, so switching paths cannot reorder.
        # Eligibility is data-dependent (per flow, batched under vmap),
        # so unlike the fluid engine's timer epoch this runs every slot
        # when the plane is armed; the Python-level gate keeps the
        # pinned-path program untouched otherwise.
        if wants_redecide(cfg):
            gap_steps = max(cfg.flowlet_gap_us // cfg.dt_us, 1)
            idle = st.fq.sum(-1) <= 0.0
            st = redecide_tick(t, st, ar, cfg,
                               idle & ((t - st.last_tx) >= gap_steps))

        # flow/link geometry of the routed flows
        pf = st.flow_path
        routed = pf >= 0
        links_f = ar.path_links[jnp.maximum(pf, 0)]             # (F,H)
        geom_ok = (links_f >= 0) & routed[:, None]
        lidx = jnp.maximum(links_f, 0)

        # 3) PFC XOFF/XON hysteresis on the instantaneous queue depth;
        # the new state lands in the pause ring at slot t and is read
        # back by upstream transmitters with backward propagation delay.
        pause = jnp.where(st.q_bytes > xoff, True,
                          jnp.where(st.q_bytes < xon, False, st.pfc_pause))
        hist_pause = st.hist_pause.at[:, jnp.asarray(t % HIST,
                                                     jnp.int32)].set(
            pause, mode=engine.RING_SCATTER_MODE)
        st = dataclasses.replace(st, pfc_pause=pause, hist_pause=hist_pause)
        pause_flat = hist_pause.reshape(-1)

        # 4) injection: CC-paced credit, rate-BDP window, whole packets.
        # The NIC sits at the ingress switch, so its pause gate reads the
        # first link's *current* XOFF state (zero propagation).
        act = st.active & routed
        win = jnp.maximum(st.rate * st.rtt_steps.astype(jnp.float32) * dt,
                          mtu)
        inflight = st.fq.sum(-1)
        credit = jnp.where(act, st.credit + st.rate * dt, 0.0)
        credit = jnp.minimum(credit, win)            # pause != stored burst
        avail = jnp.minimum(credit, jnp.clip(win - inflight, 0.0, None))
        l0 = lidx[:, 0]
        avail = jnp.where(act & ~pause[l0], avail, 0.0)
        inject = jnp.where(st.remaining <= avail, st.remaining,
                           jnp.floor(avail / mtu) * mtu)
        # ingress buffer space is a hard bound (byte-conserving even when
        # the delayed PFC gate reacts too late)
        space0 = jnp.clip(buf - st.q_bytes, 0.0, None)
        inj_factor = jnp.minimum(1.0, space0 / jnp.maximum(seg(inject, l0),
                                                           1e-9))
        scaled = inject * inj_factor[l0]
        # re-quantize a space-limited injection to whole packets so the
        # packet model survives buffer pressure (the exact-runt path is
        # the unscaled branch and stays byte-exact)
        inject = jnp.where(scaled < inject,
                           jnp.floor(scaled / mtu) * mtu, inject)
        st = dataclasses.replace(
            st,
            remaining=st.remaining - inject,
            credit=jnp.where(act, credit - inject, 0.0))

        # 5) hop-by-hop store-and-forward under per-link budgets.
        # Serving hops in path order lets a packet cross an idle path
        # within one slot (cut-through) while the shared ``served``
        # accumulator keeps every link inside cap x dt no matter how many
        # hop positions it appears at. ``q_now`` tracks intra-slot depth
        # for the buffer-space acceptance factors.
        cap_nom = ar.link_cap
        if cfg.has_degrade:
            cap_nom = cap_nom * jnp.where(t >= ar.link_deg_step,
                                          ar.link_deg_factor, 1.0)
        cap = jnp.where(st.link_alive, cap_nom, 1e-9)
        budget = cap * dt
        fq = st.fq.at[:, 0].add(inject)
        served = jnp.zeros((L,), jnp.float32)
        in_l = seg(inject, l0)                       # arrivals per link
        q_now = st.q_bytes + in_l
        delivered_add = jnp.zeros_like(st.delivered)
        for h in range(H):
            lh = lidx[:, h]
            okh = geom_ok[:, h]
            if h + 1 < H:
                lnext = links_f[:, h + 1]
                has_next = lnext >= 0
                lnextc = jnp.maximum(lnext, 0)
                # PFC gate: the downstream queue's pause state, read one
                # backward propagation of THIS link late (the pause frame
                # travels upstream over hop h's fiber)
                pd = ar.link_delay_us[lh] // cfg.dt_us
                pslot = jnp.asarray((t - pd) % HIST, jnp.int32)
                paused_next = pause_flat[lnextc * HIST + pslot] & has_next
            else:
                has_next = jnp.zeros_like(okh)
                lnextc = lh
                paused_next = jnp.zeros_like(okh)
            # in checked mode the PFC send gate routes through the
            # sanitizer seam (identity in production; the pfc_lossless
            # mutation corrupts it to prove check_pfc fires)
            gate = sanitize.pfc_gate(okh, paused_next) if checks_on \
                else (okh & ~paused_next)
            sendable = jnp.where(gate, fq[:, h], 0.0)
            demand = seg(sendable, lh)
            f_serv = jnp.minimum(1.0, jnp.clip(budget - served, 0.0, None)
                                 / jnp.maximum(demand, 1e-9))
            out = sendable * f_serv[lh]
            # downstream buffer acceptance (delivery is never blocked)
            offered_in = seg(jnp.where(has_next, out, 0.0), lnextc)
            f_in = jnp.minimum(1.0, jnp.clip(buf - q_now, 0.0, None)
                               / jnp.maximum(offered_in, 1e-9))
            out = out * jnp.where(has_next, f_in[lnextc], 1.0)
            fwd = jnp.where(has_next, out, 0.0)
            if checks_on:
                # pfc_lossless: XOFF downstream => nothing forwarded
                sanitize.check_pfc(fwd, paused_next)
            fq = fq.at[:, h].add(-out)
            if h + 1 < H:
                fq = fq.at[:, h + 1].add(fwd)
            served = served + seg(out, lh)
            in_l = in_l + seg(fwd, lnextc)
            q_now = q_now - seg(out, lh) + seg(fwd, lnextc)
            delivered_add = delivered_add + jnp.where(has_next, 0.0, out)

        q_new = seg(jnp.where(geom_ok, fq, 0.0).reshape(-1),
                    lidx.reshape(-1))
        # offered-load utilization: standing backlog + every byte that
        # arrived wanting service this slot, over the service capacity —
        # exceeds 1 under overload and stays high while PFC-paused
        # backlog sits unserved, matching the fluid engine's
        # offered/cap semantics for the HPCC law and RedTE's weights
        util = (st.q_bytes + in_l) / jnp.maximum(budget, 1e-9)
        hslot = jnp.asarray(t % HIST, jnp.int32)
        st = dataclasses.replace(
            st, fq=fq, q_bytes=q_new,
            delivered=st.delivered + delivered_add,
            hist_q=st.hist_q.at[:, hslot].set(
                q_new, mode=engine.RING_SCATTER_MODE),
            hist_u=st.hist_u.at[:, hslot].set(
                util, mode=engine.RING_SCATTER_MODE),
            u_ewma=st.u_ewma * 0.99 + 0.01 * jnp.minimum(util, 1.0),
            serv_bytes=st.serv_bytes + served)

        # 5b) flowlet clock: a flow is "transmitting" any slot it injects
        # or still has bytes queued somewhere — the idle gap the flowlet
        # detector measures starts when both go to zero. (inject covers
        # the inject-and-cut-through-in-one-slot case.)
        if wants_redecide(cfg):
            busy = (inject > 0.0) | (st.fq.sum(-1) > 0.0)
            st = dataclasses.replace(
                st, last_tx=jnp.where(busy, jnp.int32(0) + t, st.last_tx))

        # 6) CC rate update from the RTT-delayed rings (shared laws)
        links_ok = geom_ok & st.active[:, None]
        st = _cc_update(t, st, ar, cfg, pf, links_f, links_ok)

        # 7) completion by delivery: all bytes injected AND every hop
        # queue fully drained (the final drain is exact in f32: the last
        # service factor is 1, so fq hits 0.0, not an epsilon).
        newly_done = st.active & (st.remaining <= 0.0) & (st.fq.sum(-1) <= 0.0)
        prop = ar.path_prop[jnp.maximum(pf, 0)].astype(jnp.float32)
        fct = (t + 1) * dt - ar.f_arr_us + prop
        st = dataclasses.replace(
            st,
            active=st.active & ~newly_done,
            done=st.done | newly_done,
            fct_us=jnp.where(newly_done, fct, st.fct_us))

        # 8) RedTE periodic split-ratio re-optimization (shared tick)
        st = redte_tick(t, st, ar, cfg)

        # 9) debug-mode physics invariants (Python gate: the unchecked
        # trace carries no extra ops)
        if checks_on:
            st = sanitize.step_check(t, st, ar, cfg)

        return st, None

    return step


def run_impl(arrs: SimArrays, state: PacketState, cfg: SimConfig) -> PacketState:
    """Unjitted scan body — the sweep engine vmaps/shard_maps this and
    wraps its own single jit around the whole batch."""
    step = make_step(arrs, cfg)
    final, _ = jax.lax.scan(step, state, jnp.arange(cfg.num_steps))
    return final


_run_jit = jax.jit(run_impl, static_argnames=("cfg",))


def run(arrs: SimArrays, state: PacketState, cfg: SimConfig) -> PacketState:
    """Single-experiment entry: the plain jit, or the checkify-wrapped
    sanitizer program when ``cfg.checks`` is set (raises
    ``checkify.JaxRuntimeError`` on an invariant violation)."""
    if sanitize.enabled(cfg):
        return sanitize.run_with_checks(run_impl, arrs, state, cfg)
    return _run_jit(arrs, state, cfg)
