"""Batched scenario-sweep engine: many experiment cells, one XLA program.

The paper's evaluation is a grid — topologies x workloads x loads x
policies x seeds (§6, Figs. 5-11). Running each ``ExpSpec`` through
``fluid.run`` one at a time re-traces and re-compiles the jitted scan for
every cell. This engine instead:

1. groups cells by their *static* key — everything that changes the
   traced program: scenario string (topology + schedules), simulation
   engine (fluid/packet, see ``repro.netsim.engine``), cc law,
   cap_scale, duration, the re-decision-plane knobs
   (``flowlet_gap_us``/``redecide_period_us``/``n_subflows``), and the
   Select/PathQ/Cong parameter dataclasses.
   Policy is NOT part of the key: ``fluid`` dispatches it dynamically on
   the per-cell ``policy_code`` (cfg.policy == "sweep"), so an entire
   load x policy figure grid is ONE group — re-decision-capable policies
   (``engine.REDECIDE_POLICIES``) included, their tick is gated per cell
   by ``policy_code`` so pinned cells sharing the trace stay bit-exact;
2. pads each group's per-cell arrays (flow tables to the max flow count,
   arrival buckets to the max per-step batch — both padding-invariant by
   construction, see ``fluid._route_arrivals``'s out-of-bounds-drop
   scatter) and stacks them along a leading cell axis;
3. runs the whole group as ONE jitted invocation — one trace, one
   compile, one device dispatch — either ``jax.vmap`` over the cell axis
   (dispatch-bound small cells) or a compiled ``jax.lax.map`` loop over
   cells (compute-bound large cells, where vmap's batched-scatter
   lowering costs ~30% on CPU), and optionally ``jax.shard_map``s the
   cell axis across the host mesh (``repro.launch.mesh.make_host_mesh``)
   when multiple devices exist.

Per-cell results are bit-for-bit identical to the sequential loop (the
tier-1 suite asserts exact FCT equality): vmap batches the same IEEE ops,
padded flows never activate, and padded arrival slots scatter out of
bounds and drop.
"""
from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401  (installs the jax.shard_map forward-compat alias)
from repro.launch.mesh import make_host_mesh
from repro.netsim import engine as enginemod
from repro.netsim import fluid, metrics, sanitize
from repro.netsim.engine import SimArrays, SimState
from repro.netsim.experiment import (ExpSpec, build_world, make_flows,
                                     run_experiment, spec_to_cfg)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CellArrays:
    """The per-cell slice of ``SimArrays`` — everything a load/seed/
    workload/policy axis can change. The rest of ``SimArrays`` (link and
    path tables, schedules, switch tables) is shared across the group and
    enters the vmap unbatched."""
    arrivals: jnp.ndarray      # (T, A) i32
    f_arr_us: jnp.ndarray      # (F,) f32
    f_size: jnp.ndarray        # (F,) f32
    f_pair: jnp.ndarray        # (F,) i32
    f_id: jnp.ndarray          # (F,) u32
    policy_code: jnp.ndarray   # () i32


@dataclasses.dataclass
class CellResult:
    """One cell's outputs, sliced back out of the batch (numpy)."""
    spec: ExpSpec
    stats: metrics.FCTStats
    util: np.ndarray           # (L,) effective-capacity utilization
    final: SimpleNamespace     # done / fct_us / flow_path / serv_bytes / c_path
    flows: object              # the cell's FlowSet
    # foreground/background split when the cell doses cross-traffic
    # (spec.bg_load > 0): stats over the measured pairs vs the rest.
    # stats_fg == stats and stats_bg is None for all-foreground cells.
    stats_fg: metrics.FCTStats = None
    stats_bg: metrics.FCTStats = None


@dataclasses.dataclass
class SweepReport:
    results: List[CellResult]  # in the order of the input specs
    num_cells: int
    num_groups: int
    wall_s: float
    group_cells: List[int]     # cells per compiled group

    def __iter__(self):
        return iter(self.results)


def static_key(spec: ExpSpec):
    """Everything that forces a separate trace/compile. Policy is
    deliberately absent (dynamic dispatch); load/seed/workload/pairs/
    bg_load/load_sched only change array *contents* — a whole diurnal
    schedule grid (``ExpSpec.load_sched``) batches into one trace."""
    scen, _ = build_world(spec.topology)
    return (spec.topology, dataclasses.replace(
        spec_to_cfg(spec, scen), policy="sweep"))


def _pad_tail(a: np.ndarray, n: int, fill) -> np.ndarray:
    """Pad axis 0 of ``a`` to length ``n`` with ``fill``."""
    if a.shape[0] == n:
        return np.asarray(a)
    out = np.full((n,) + a.shape[1:], fill, dtype=np.asarray(a).dtype)
    out[: a.shape[0]] = a
    return out


def _pad_cell(arrs: SimArrays, state: SimState, F: int, A: int):
    """Pad one built cell to the group's (F, A). Padded flows never appear
    in ``arrivals`` (pad = -1), never activate, and contribute exact 0.0
    to every link sum, so results are unchanged. Which fields carry a
    leading flow axis (and their inert pad values) is the engine core's
    contract (``engine.FLOW_FIELDS`` — the packet engine's extra state is
    covered there too, and the state's own dataclass type is rebuilt)."""
    T = arrs.arrivals.shape[0]
    arrivals = np.full((T, A), -1, np.int32)
    arrivals[:, : arrs.arrivals.shape[1]] = np.asarray(arrs.arrivals)
    cell = CellArrays(
        arrivals=jnp.asarray(arrivals),
        f_arr_us=jnp.asarray(_pad_tail(np.asarray(arrs.f_arr_us), F, 0.0)),
        f_size=jnp.asarray(_pad_tail(np.asarray(arrs.f_size), F, 0.0)),
        f_pair=jnp.asarray(_pad_tail(np.asarray(arrs.f_pair), F, 0)),
        f_id=jnp.asarray(_pad_tail(np.asarray(arrs.f_id), F, 0)),
        policy_code=arrs.policy_code,
    )
    st = {}
    for f in dataclasses.fields(type(state)):
        v = getattr(state, f.name)
        if f.name in enginemod.FLOW_FIELDS:
            st[f.name] = jnp.asarray(_pad_tail(
                np.asarray(v), F, enginemod.STATE_PAD.get(f.name, 0)))
        else:
            st[f.name] = v            # per-link / per-pair: shared shape
    return cell, type(state)(**st)


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


# auto batch-mode crossover (flows): below this, a grid is dispatch-bound
# and vmap's wider ops win; above it, it is compute-bound and vmap's
# batched-scatter lowering costs ~30% on CPU while lax.map (a compiled
# loop over cells inside the same single trace) runs at single-cell cost.
_VMAP_MAX_FLOWS = 512


def _group_runner(shared: SimArrays, cfg, mesh=None, mode: str = "vmap"):
    """One jitted callable running every cell of a group at once. The
    simulation backend is the group's static ``cfg.engine`` (part of the
    trace key), so fluid and packet cells batch in separate groups."""
    eng = enginemod.get_engine(cfg.engine)

    def one(cell: CellArrays, st: SimState):
        arrs = dataclasses.replace(
            shared, arrivals=cell.arrivals, f_arr_us=cell.f_arr_us,
            f_size=cell.f_size, f_pair=cell.f_pair, f_id=cell.f_id,
            policy_code=cell.policy_code)
        return eng.run_impl(arrs, st, cfg)

    def run_cells(cells: CellArrays, states: SimState):
        if mode == "vmap":
            return jax.vmap(one)(cells, states)
        return jax.lax.map(lambda args: one(*args), (cells, states))

    if mesh is not None:
        from jax.sharding import PartitionSpec as P
        run_cells = jax.shard_map(run_cells, mesh=mesh,
                                  in_specs=(P("data"), P("data")),
                                  out_specs=P("data"), check_vma=False)
    if sanitize.enabled(cfg):
        return sanitize.checked_call(run_cells)
    return jax.jit(run_cells)


def _chunk_by_flows(built, idxs, max_pad_frac: float):
    """Split a group's cells into chunks whose flow counts are within
    ``max_pad_frac`` of the chunk max. Padding a 30%-load cell to an
    80%-load cell's flow table makes the vmapped scan *compute* the
    padding (inert, but not free) — on compute-dominated grids that
    waste exceeds the saved traces, so bounded-waste chunks beat one
    maximal batch. Cells with near-equal F (seed/policy/workload axes)
    still share one trace."""
    order = sorted(range(len(built)), key=lambda j: -built[j][1].f_arr_us.shape[0])
    chunks, cur, cur_fmax = [], [], None
    for j in order:
        f = built[j][1].f_arr_us.shape[0]
        if cur and f < (1.0 - max_pad_frac) * cur_fmax:
            chunks.append(cur)
            cur, cur_fmax = [], None
        if not cur:
            cur_fmax = f
        cur.append(j)
    if cur:
        chunks.append(cur)
    return [([built[j] for j in chunk], [idxs[j] for j in chunk])
            for chunk in chunks]


def run_sweep(specs: Sequence[ExpSpec], sequential: bool = False,
              use_mesh: bool = False, devices: Optional[int] = None,
              max_pad_frac: float = 0.35,
              batch_mode: str = "auto") -> SweepReport:
    """Run a grid of experiment cells, batching compatible cells.

    Args:
      specs: the grid, any mix of scenarios/loads/policies/seeds/...
      sequential: run the classic one-cell-at-a-time loop instead (the
        before/after baseline for the batched engine; also what the
        equivalence test compares against).
      use_mesh: additionally shard the cell axis across host devices via
        ``shard_map`` when more than one device is visible. With a single
        device this is a no-op.
      devices: cap on the mesh size (default: all visible devices).
      max_pad_frac: flow-count padding budget per batch — cells whose
        flow tables are more than this fraction smaller than the largest
        cell in a batch go to their own chunk (see ``_chunk_by_flows``).
      batch_mode: "vmap" (cells as a leading batch axis), "map" (a
        compiled lax.map loop over cells inside one trace), or "auto"
        (vmap for small dispatch-bound cells, map past the
        ``_VMAP_MAX_FLOWS`` crossover). All modes share one trace per
        chunk and produce bit-identical results.
    """
    t0 = time.perf_counter()
    if sequential:
        results = []
        for spec in specs:
            stats, util, (_, table, flows, cfg, final) = run_experiment(spec)
            view = SimpleNamespace(
                done=np.asarray(final.done),
                fct_us=np.asarray(final.fct_us),
                flow_path=np.asarray(final.flow_path),
                serv_bytes=np.asarray(final.serv_bytes),
                c_path=np.asarray(final.c_path))
            fg, bg = metrics.fg_bg_stats(view, table, flows, cfg,
                                         overall=stats)
            results.append(CellResult(spec=spec, stats=stats, util=util,
                                      final=view, flows=flows,
                                      stats_fg=fg, stats_bg=bg))
        return SweepReport(results, len(results), len(results),
                           time.perf_counter() - t0, [1] * len(results))

    # ---- group by static key, preserving input order within groups
    groups: dict = {}
    for i, spec in enumerate(specs):
        groups.setdefault(static_key(spec), []).append(i)

    ndev = 1
    if use_mesh:
        ndev = min(devices or len(jax.devices()), len(jax.devices()))

    results: List[Optional[CellResult]] = [None] * len(specs)
    group_cells: List[int] = []
    for (topology, cfg), idxs in groups.items():
        scen, table = build_world(topology)
        eng = enginemod.get_engine(cfg.engine)
        # narrow the dynamic dispatch to the policies actually present
        present = {specs[i].policy for i in idxs}
        cfg = dataclasses.replace(cfg, sweep_policies=tuple(
            p for p in fluid.POLICIES if p in present))
        built = []
        for i in idxs:
            spec = specs[i]
            flows = make_flows(spec, scen, table)
            # build with the concrete policy so policy_code is baked; the
            # batched run itself uses the "sweep" meta-policy cfg
            cell_cfg = dataclasses.replace(cfg, policy=spec.policy)
            arrs, st = eng.build(table, flows, cell_cfg)
            built.append((flows, arrs, st))

        for chunk, chunk_idxs in _chunk_by_flows(built, idxs, max_pad_frac):
            group_cells.append(len(chunk))
            Fmax = max(a.f_arr_us.shape[0] for _, a, _ in chunk)
            Amax = max(a.arrivals.shape[1] for _, a, _ in chunk)
            padded = [_pad_cell(a, s, Fmax, Amax) for _, a, s in chunk]

            mesh = None
            ncells = len(padded)
            if ndev > 1:
                # pad the cell axis to a multiple of the mesh so
                # shard_map gets equal shards; clones are dropped after
                mesh = make_host_mesh(data=ndev)
                while len(padded) % ndev:
                    padded.append(padded[0])
            cells = _stack([c for c, _ in padded])
            states = _stack([s for _, s in padded])

            # blank the per-cell fields before closure capture: one()
            # replaces them per cell, so leaving them would only bake
            # chunk[0]'s (T,A) arrivals + flow tables into the compiled
            # program as dead constants
            shared = dataclasses.replace(
                chunk[0][1], arrivals=None, f_arr_us=None, f_size=None,
                f_pair=None, f_id=None, policy_code=None)
            mode = batch_mode
            if mode == "auto":
                mode = "vmap" if Fmax <= _VMAP_MAX_FLOWS else "map"
            final = _group_runner(shared, cfg, mesh, mode)(cells, states)
            final = jax.tree_util.tree_map(np.asarray, final)

            for j, i in enumerate(chunk_idxs[:ncells]):
                spec, (flows, _, _) = specs[i], chunk[j]
                F = flows.num_flows
                view = SimpleNamespace(done=final.done[j, :F],
                                       fct_us=final.fct_us[j, :F],
                                       flow_path=final.flow_path[j, :F],
                                       serv_bytes=final.serv_bytes[j],
                                       c_path=final.c_path[j])
                stats = metrics.fct_stats(view, table, flows, cfg)
                util = metrics.link_utilization(view, shared, cfg)
                fg, bg = metrics.fg_bg_stats(view, table, flows, cfg,
                                             overall=stats)
                results[i] = CellResult(spec=spec, stats=stats, util=util,
                                        final=view, flows=flows,
                                        stats_fg=fg, stats_bg=bg)

    return SweepReport(results, len(specs), len(group_cells),
                       time.perf_counter() - t0, group_cells)
