"""Inter-DC topologies used in the paper's evaluation (§6, Fig. 4).

A topology is a small directed graph of DCI switches: ``links[i] =
(src, dst, cap_gbps, delay_us)``. Intra-DC fabrics are abstracted away —
the paper provisions them (100G leaf-spine, 400G DCI uplinks) precisely
so they are never the bottleneck; all placement dynamics happen on the
inter-DC links, which is what we model.

Provided:
- ``testbed_8dc``    : Fig. 1a / §6.1 — DC1..DC8, six candidate routes
  DC1->DC8 through DC2..DC7 with {200,200,100,100,40,40} Gbps long-haul
  links, one low-delay (5 ms) and one high-delay (250 ms) member per
  capacity class, and fat 400 Gbps / 1 ms tail hops so the long-haul link
  defines each path.
- ``bso_13dc``       : §6.2 — a 13-DC European backbone in the style of
  BSONetworkSolutions (Internet Topology Zoo). The Zoo's exact edge list
  is not redistributable offline, so we build a structurally matched
  stand-in: 13 nodes, sparse ring+chord mesh, delays quantized to
  {1, 5, 10} ms (200/1000/2000 km) and heterogeneous 40-400 Gbps
  capacities, tuned so ~26% of node pairs see multiple first-hop-distinct
  candidate routes (paper: 20/78 = 25.6%).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

Link = Tuple[int, int, int, int]  # (src, dst, cap_gbps, delay_us)


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    num_nodes: int
    links: List[Link]              # directed (both directions listed)

    @property
    def num_links(self) -> int:
        return len(self.links)

    def arrays(self):
        a = np.asarray(self.links, np.int64)
        return (a[:, 0].astype(np.int32), a[:, 1].astype(np.int32),
                a[:, 2].astype(np.int32), a[:, 3].astype(np.int32))


def _bidir(edges: List[Link]) -> List[Link]:
    out: List[Link] = []
    for s, d, c, dl in edges:
        out.append((s, d, c, dl))
        out.append((d, s, c, dl))
    return out


def testbed_8dc() -> Topology:
    """Fig. 1a. Nodes 0..7 = DC1..DC8. Six 2-hop routes DC1->DC8."""
    ms = 1000
    # (transit DC, long-haul capacity Gbps, long-haul one-way delay us)
    # Delays span the paper's stated 5-250 ms range with one low-delay and
    # one high-delay member per capacity class. The intermediate values
    # (25/35 ms) matter: they put the 4th-cheapest path within beta*255
    # fused-cost points of the kept set, so the congestion term can swap a
    # hot low-delay path out — the adaptivity the paper's ablation
    # (rm-beta "fails for large transfers") demonstrates. All-extreme
    # delays (5 vs 250 only) would make the kept set static under (3,1).
    classes = [
        (1, 200, 250 * ms),   # DC2: high-capacity, high-delay
        (2, 200, 25 * ms),    # DC3: high-capacity, low-delay
        (3, 100, 35 * ms),    # DC4: medium, higher-delay
        (4, 100, 5 * ms),     # DC5: medium, low-delay
        (5, 40, 5 * ms),      # DC6: low, low-delay
        (6, 40, 250 * ms),    # DC7: low, high-delay
    ]
    edges: List[Link] = []
    for dc, cap, delay in classes:
        edges.append((0, dc, cap, delay))      # DC1 -> transit (long haul)
        edges.append((dc, 7, 400, 1 * ms))     # transit -> DC8 (fat tail hop)
    return Topology("testbed-8dc", 8, _bidir(edges))


def bso_13dc() -> Topology:
    """13-DC European backbone stand-in (BSONetworkSolutions style).

    Delay tiers: 1 ms (~200 km), 5 ms (~1000 km), 10 ms (~2000 km).
    Mixed 40-400 Gbps provisioning; sparse enough that only a quarter of
    pairs are truly multi-path (paper §6.2: gains dilute system-wide).
    """
    ms = 1000
    edges: List[Link] = [
        # core western-European ring
        (0, 1, 200, 1 * ms), (1, 2, 200, 1 * ms), (2, 3, 100, 5 * ms),
        (3, 4, 100, 1 * ms), (4, 5, 200, 5 * ms), (5, 6, 100, 1 * ms),
        (6, 7, 100, 5 * ms), (7, 8, 40, 1 * ms), (8, 9, 100, 5 * ms),
        (9, 10, 200, 1 * ms), (10, 11, 40, 5 * ms), (11, 12, 100, 1 * ms),
        (12, 0, 200, 10 * ms),
        # long-haul chords (2000 km class) creating multi-path pairs;
        # this set yields 26.3% multi-path pairs (paper: 20/78 = 25.6%)
        (0, 4, 400, 10 * ms), (2, 6, 40, 10 * ms), (5, 12, 100, 10 * ms),
    ]
    return Topology("bso-13dc", 13, _bidir(edges))


def duplex_line(num_nodes: int = 3, cap: int = 100, delay_us: int = 5000) -> Topology:
    """Tiny chain for unit tests."""
    edges = [(i, i + 1, cap, delay_us) for i in range(num_nodes - 1)]
    return Topology("line", num_nodes, _bidir(edges))


def segmented_parallel(route_caps, route_delays_us, segs: int = 2,
                       tail_cap: int = 400, tail_delay_us: int = 1000) -> Topology:
    """Parallel long-haul routes where each route's long haul is a chain of
    ``segs`` OTN segments in series (MatchRDMA-style segmented links: a
    2000 km haul is really several amplified/regenerated spans, and a
    single span can fail or degrade independently).

    Node layout: 0 = src DC, then ``segs`` transit nodes per route, then
    dst = 1 + len(routes)*segs. Route i gets capacity ``route_caps[i]`` on
    every segment and its one-way delay ``route_delays_us[i]`` split evenly
    across segments, followed by a fat tail hop into the destination (the
    same "long haul defines the path" construction as the 8-DC testbed).

    With the default ``MAX_HOPS=5`` path enumeration, ``segs`` must stay
    <= 4 (segs long-haul hops + 1 tail hop per route).
    """
    n = len(route_caps)
    assert len(route_delays_us) == n
    if not 1 <= segs <= 4:   # paths.MAX_HOPS=5 minus the tail hop
        raise ValueError(f"segs={segs} unroutable: paths are segs+1 hops "
                         "and candidate enumeration caps at 5 (paths.MAX_HOPS)")
    dst = 1 + n * segs
    edges: List[Link] = []
    for i, (cap, delay) in enumerate(zip(route_caps, route_delays_us)):
        seg_delay = max(int(delay) // segs, 1)
        nodes = [0] + [1 + i * segs + j for j in range(segs)]
        for a, b in zip(nodes[:-1], nodes[1:]):
            edges.append((a, b, int(cap), seg_delay))
        edges.append((nodes[-1], dst, tail_cap, tail_delay_us))
    return Topology(f"segmented-parallel-{n}x{segs}", dst + 1, _bidir(edges))


# ------------------------------------------------- large-scale 2000 km WAN
# Declared hardware classes for the wan_2000km generator; the generator
# invariants test asserts every emitted link against these.
WAN_CAP_CLASSES = (400, 200, 100, 40)           # Gbps per haul
WAN_DELAY_CLASSES_US = (8_000, 10_000, 12_000)  # one-way per ~2000 km haul


@dataclasses.dataclass(frozen=True)
class WanWorld:
    """A generated WAN plus the metadata the scenario layer needs."""
    topology: Topology
    main_pair: Tuple[int, int]
    dc_nodes: Tuple[int, ...]        # traffic endpoints (segment nodes excluded)
    main_haul_links: Tuple[int, ...]  # first directed link of each main-pair
    #                                   parallel haul, fattest first


def wan_2000km(dcs: int = 20, segs: int = 2, chords: int = 6,
               seed: int = 0) -> WanWorld:
    """Large-scale heterogeneous 2000 km-class WAN (the paper's headline
    "large-scale NS-3 simulations under the 2000 km inter-DC scenario",
    stretched into MatchRDMA's segmented-OTN regime).

    Structure: ``dcs`` DC nodes on a ring of long-haul fiber hauls, plus
    ``chords`` random shortcut hauls and two extra *parallel* hauls on
    the DC0<->DC1 edge (so the designated main pair has a fast-fat /
    medium / slow-thin candidate mix like the 8-DC testbed). Every haul
    is ~2000 km: one-way delay from ``WAN_DELAY_CLASSES_US``, capacity
    from ``WAN_CAP_CLASSES``, and each haul is a chain of ``segs``
    amplified/regenerated OTN segments (dedicated intermediate nodes) so
    a single span can fail or degrade independently.

    Deterministic under ``(dcs, segs, chords, seed)``. DC nodes are
    0..dcs-1; segment nodes follow. Paths between DCs are chains of
    whole hauls, so candidate enumeration needs ``max_hops = 2 * segs``
    (two hauls) and a detour budget of one extra haul — the scenario
    layer passes those via ``Scenario.max_hops``/``detour_*``.
    """
    if dcs < 4:
        raise ValueError(f"wan_2000km needs dcs >= 4, got {dcs}")
    if segs < 1:
        raise ValueError(f"wan_2000km needs segs >= 1, got {segs}")
    rng = np.random.default_rng(seed)
    # hauls as DC-level edges: (a, b, cap_gbps, one_way_delay_us)
    hauls: List[Link] = []
    # the main pair's three parallel hauls, fattest first (testbed-style
    # heterogeneity: fast-fat / medium / slow-thin)
    main = [(0, 1, 200, WAN_DELAY_CLASSES_US[0]),
            (0, 1, 100, WAN_DELAY_CLASSES_US[1]),
            (0, 1, 40, WAN_DELAY_CLASSES_US[2])]
    hauls += main
    for i in range(1, dcs):   # rest of the ring (edge 0-1 is covered above)
        cap = int(rng.choice(WAN_CAP_CLASSES))
        dl = int(rng.choice(WAN_DELAY_CLASSES_US))
        hauls.append((i, (i + 1) % dcs, cap, dl))
    seen = {(a, b) for a, b, _, _ in hauls}
    tries = 0
    placed = 0
    while placed < chords and tries < 20 * chords:
        tries += 1
        a = int(rng.integers(0, dcs))
        off = int(rng.choice([2, 3, max(dcs // 2, 4)]))
        b = (a + off) % dcs
        if a == b or (a, b) in seen or (b, a) in seen:
            continue
        seen.add((a, b))
        hauls.append((a, b, int(rng.choice(WAN_CAP_CLASSES)),
                      int(rng.choice(WAN_DELAY_CLASSES_US))))
        placed += 1
    if placed < chords:
        # never return a sparser WAN than the scenario string advertises —
        # downstream claims (advertised-pair counts, multipath fraction)
        # would silently describe a different topology
        raise ValueError(
            f"wan_2000km(dcs={dcs}) could only place {placed} of {chords} "
            "requested chords (distinct {2,3,dcs/2}-offset slots exhausted); "
            "lower chords= or raise dcs=")

    # expand each haul into `segs` spans through dedicated segment nodes;
    # _bidir emits (fwd, rev) per span, so a haul's first directed link
    # (the one schedules target) is at index 2 * (its first span's row)
    edges: List[Link] = []
    next_node = dcs
    main_first: List[int] = []
    for h, (a, b, cap, dl) in enumerate(hauls):
        seg_delay = max(dl // segs, 1)
        nodes = [a] + [next_node + j for j in range(segs - 1)] + [b]
        next_node += segs - 1
        if h < len(main):
            main_first.append(2 * len(edges))
        for u, v in zip(nodes[:-1], nodes[1:]):
            edges.append((u, v, cap, seg_delay))
    t = Topology(f"wan-2000km-{dcs}dc-{segs}seg-s{seed}", next_node,
                 _bidir(edges))
    return WanWorld(topology=t, main_pair=(0, 1),
                    dc_nodes=tuple(range(dcs)),
                    main_haul_links=tuple(main_first))


def delay_jitter(base: Topology, frac: float = 0.2, seed: int = 0) -> Topology:
    """Apply asymmetric delay jitter: every *directed* link's propagation
    delay is independently scaled by U[1-frac, 1+frac], so forward and
    reverse directions of the same fiber diverge — the delay-asymmetry
    regime long-haul RTT estimators (and the paper's delayScore) must
    tolerate."""
    rng = np.random.default_rng(seed)
    links = [(s, d, c, max(int(round(dl * (1.0 + frac * (2.0 * rng.random() - 1.0)))), 1))
             for (s, d, c, dl) in base.links]
    return Topology(f"{base.name}-jitter{frac}s{seed}", base.num_nodes, links)


def parallel_paths(caps=(100, 100), delays_us=(5000, 5000)) -> Topology:
    """src=0, dst=N+1, one transit node per parallel path — the minimal
    multi-path fixture for routing tests."""
    edges: List[Link] = []
    n = len(caps)
    for i, (c, d) in enumerate(zip(caps, delays_us)):
        edges.append((0, 1 + i, c, d))
        edges.append((1 + i, n + 1, 400, 1000))
    return Topology("parallel", n + 2, _bidir(edges))
