"""Inter-DC topologies used in the paper's evaluation (§6, Fig. 4).

A topology is a small directed graph of DCI switches: ``links[i] =
(src, dst, cap_gbps, delay_us)``. Intra-DC fabrics are abstracted away —
the paper provisions them (100G leaf-spine, 400G DCI uplinks) precisely
so they are never the bottleneck; all placement dynamics happen on the
inter-DC links, which is what we model.

Provided:
- ``testbed_8dc``    : Fig. 1a / §6.1 — DC1..DC8, six candidate routes
  DC1->DC8 through DC2..DC7 with {200,200,100,100,40,40} Gbps long-haul
  links, one low-delay (5 ms) and one high-delay (250 ms) member per
  capacity class, and fat 400 Gbps / 1 ms tail hops so the long-haul link
  defines each path.
- ``bso_13dc``       : §6.2 — a 13-DC European backbone in the style of
  BSONetworkSolutions (Internet Topology Zoo). The Zoo's exact edge list
  is not redistributable offline, so we build a structurally matched
  stand-in: 13 nodes, sparse ring+chord mesh, delays quantized to
  {1, 5, 10} ms (200/1000/2000 km) and heterogeneous 40-400 Gbps
  capacities, tuned so ~26% of node pairs see multiple first-hop-distinct
  candidate routes (paper: 20/78 = 25.6%).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

Link = Tuple[int, int, int, int]  # (src, dst, cap_gbps, delay_us)


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    num_nodes: int
    links: List[Link]              # directed (both directions listed)

    @property
    def num_links(self) -> int:
        return len(self.links)

    def arrays(self):
        a = np.asarray(self.links, np.int64)
        return (a[:, 0].astype(np.int32), a[:, 1].astype(np.int32),
                a[:, 2].astype(np.int32), a[:, 3].astype(np.int32))


def _bidir(edges: List[Link]) -> List[Link]:
    out: List[Link] = []
    for s, d, c, dl in edges:
        out.append((s, d, c, dl))
        out.append((d, s, c, dl))
    return out


def testbed_8dc() -> Topology:
    """Fig. 1a. Nodes 0..7 = DC1..DC8. Six 2-hop routes DC1->DC8."""
    ms = 1000
    # (transit DC, long-haul capacity Gbps, long-haul one-way delay us)
    # Delays span the paper's stated 5-250 ms range with one low-delay and
    # one high-delay member per capacity class. The intermediate values
    # (25/35 ms) matter: they put the 4th-cheapest path within beta*255
    # fused-cost points of the kept set, so the congestion term can swap a
    # hot low-delay path out — the adaptivity the paper's ablation
    # (rm-beta "fails for large transfers") demonstrates. All-extreme
    # delays (5 vs 250 only) would make the kept set static under (3,1).
    classes = [
        (1, 200, 250 * ms),   # DC2: high-capacity, high-delay
        (2, 200, 25 * ms),    # DC3: high-capacity, low-delay
        (3, 100, 35 * ms),    # DC4: medium, higher-delay
        (4, 100, 5 * ms),     # DC5: medium, low-delay
        (5, 40, 5 * ms),      # DC6: low, low-delay
        (6, 40, 250 * ms),    # DC7: low, high-delay
    ]
    edges: List[Link] = []
    for dc, cap, delay in classes:
        edges.append((0, dc, cap, delay))      # DC1 -> transit (long haul)
        edges.append((dc, 7, 400, 1 * ms))     # transit -> DC8 (fat tail hop)
    return Topology("testbed-8dc", 8, _bidir(edges))


def bso_13dc() -> Topology:
    """13-DC European backbone stand-in (BSONetworkSolutions style).

    Delay tiers: 1 ms (~200 km), 5 ms (~1000 km), 10 ms (~2000 km).
    Mixed 40-400 Gbps provisioning; sparse enough that only a quarter of
    pairs are truly multi-path (paper §6.2: gains dilute system-wide).
    """
    ms = 1000
    edges: List[Link] = [
        # core western-European ring
        (0, 1, 200, 1 * ms), (1, 2, 200, 1 * ms), (2, 3, 100, 5 * ms),
        (3, 4, 100, 1 * ms), (4, 5, 200, 5 * ms), (5, 6, 100, 1 * ms),
        (6, 7, 100, 5 * ms), (7, 8, 40, 1 * ms), (8, 9, 100, 5 * ms),
        (9, 10, 200, 1 * ms), (10, 11, 40, 5 * ms), (11, 12, 100, 1 * ms),
        (12, 0, 200, 10 * ms),
        # long-haul chords (2000 km class) creating multi-path pairs;
        # this set yields 26.3% multi-path pairs (paper: 20/78 = 25.6%)
        (0, 4, 400, 10 * ms), (2, 6, 40, 10 * ms), (5, 12, 100, 10 * ms),
    ]
    return Topology("bso-13dc", 13, _bidir(edges))


def duplex_line(num_nodes: int = 3, cap: int = 100, delay_us: int = 5000) -> Topology:
    """Tiny chain for unit tests."""
    edges = [(i, i + 1, cap, delay_us) for i in range(num_nodes - 1)]
    return Topology("line", num_nodes, _bidir(edges))


def segmented_parallel(route_caps, route_delays_us, segs: int = 2,
                       tail_cap: int = 400, tail_delay_us: int = 1000) -> Topology:
    """Parallel long-haul routes where each route's long haul is a chain of
    ``segs`` OTN segments in series (MatchRDMA-style segmented links: a
    2000 km haul is really several amplified/regenerated spans, and a
    single span can fail or degrade independently).

    Node layout: 0 = src DC, then ``segs`` transit nodes per route, then
    dst = 1 + len(routes)*segs. Route i gets capacity ``route_caps[i]`` on
    every segment and its one-way delay ``route_delays_us[i]`` split evenly
    across segments, followed by a fat tail hop into the destination (the
    same "long haul defines the path" construction as the 8-DC testbed).

    With the default ``MAX_HOPS=5`` path enumeration, ``segs`` must stay
    <= 4 (segs long-haul hops + 1 tail hop per route).
    """
    n = len(route_caps)
    assert len(route_delays_us) == n
    if not 1 <= segs <= 4:   # paths.MAX_HOPS=5 minus the tail hop
        raise ValueError(f"segs={segs} unroutable: paths are segs+1 hops "
                         "and candidate enumeration caps at 5 (paths.MAX_HOPS)")
    dst = 1 + n * segs
    edges: List[Link] = []
    for i, (cap, delay) in enumerate(zip(route_caps, route_delays_us)):
        seg_delay = max(int(delay) // segs, 1)
        nodes = [0] + [1 + i * segs + j for j in range(segs)]
        for a, b in zip(nodes[:-1], nodes[1:]):
            edges.append((a, b, int(cap), seg_delay))
        edges.append((nodes[-1], dst, tail_cap, tail_delay_us))
    return Topology(f"segmented-parallel-{n}x{segs}", dst + 1, _bidir(edges))


# ------------------------------------------------- large-scale 2000 km WAN
# Declared hardware classes for the wan_2000km generator; the generator
# invariants test asserts every emitted link against these.
WAN_CAP_CLASSES = (400, 200, 100, 40)           # Gbps per haul
WAN_DELAY_CLASSES_US = (8_000, 10_000, 12_000)  # one-way per ~2000 km haul


@dataclasses.dataclass(frozen=True)
class WanWorld:
    """A generated WAN plus the metadata the scenario layer needs."""
    topology: Topology
    main_pair: Tuple[int, int]
    dc_nodes: Tuple[int, ...]        # traffic endpoints (segment nodes excluded)
    main_haul_links: Tuple[int, ...]  # first directed link of each main-pair
    #                                   parallel haul, fattest first


def wan_2000km(dcs: int = 20, segs: int = 2, chords: int = 6,
               seed: int = 0) -> WanWorld:
    """Large-scale heterogeneous 2000 km-class WAN (the paper's headline
    "large-scale NS-3 simulations under the 2000 km inter-DC scenario",
    stretched into MatchRDMA's segmented-OTN regime).

    Structure: ``dcs`` DC nodes on a ring of long-haul fiber hauls, plus
    ``chords`` random shortcut hauls and two extra *parallel* hauls on
    the DC0<->DC1 edge (so the designated main pair has a fast-fat /
    medium / slow-thin candidate mix like the 8-DC testbed). Every haul
    is ~2000 km: one-way delay from ``WAN_DELAY_CLASSES_US``, capacity
    from ``WAN_CAP_CLASSES``, and each haul is a chain of ``segs``
    amplified/regenerated OTN segments (dedicated intermediate nodes) so
    a single span can fail or degrade independently.

    Deterministic under ``(dcs, segs, chords, seed)``. DC nodes are
    0..dcs-1; segment nodes follow. Paths between DCs are chains of
    whole hauls, so candidate enumeration needs ``max_hops = 2 * segs``
    (two hauls) and a detour budget of one extra haul — the scenario
    layer passes those via ``Scenario.max_hops``/``detour_*``.
    """
    if dcs < 4:
        raise ValueError(f"wan_2000km needs dcs >= 4, got {dcs}")
    if segs < 1:
        raise ValueError(f"wan_2000km needs segs >= 1, got {segs}")
    rng = np.random.default_rng(seed)
    # hauls as DC-level edges: (a, b, cap_gbps, one_way_delay_us)
    hauls: List[Link] = []
    # the main pair's three parallel hauls, fattest first (testbed-style
    # heterogeneity: fast-fat / medium / slow-thin)
    main = [(0, 1, 200, WAN_DELAY_CLASSES_US[0]),
            (0, 1, 100, WAN_DELAY_CLASSES_US[1]),
            (0, 1, 40, WAN_DELAY_CLASSES_US[2])]
    hauls += main
    for i in range(1, dcs):   # rest of the ring (edge 0-1 is covered above)
        cap = int(rng.choice(WAN_CAP_CLASSES))
        dl = int(rng.choice(WAN_DELAY_CLASSES_US))
        hauls.append((i, (i + 1) % dcs, cap, dl))
    seen = {(a, b) for a, b, _, _ in hauls}
    tries = 0
    placed = 0
    while placed < chords and tries < 20 * chords:
        tries += 1
        a = int(rng.integers(0, dcs))
        off = int(rng.choice([2, 3, max(dcs // 2, 4)]))
        b = (a + off) % dcs
        if a == b or (a, b) in seen or (b, a) in seen:
            continue
        seen.add((a, b))
        hauls.append((a, b, int(rng.choice(WAN_CAP_CLASSES)),
                      int(rng.choice(WAN_DELAY_CLASSES_US))))
        placed += 1
    if placed < chords:
        # never return a sparser WAN than the scenario string advertises —
        # downstream claims (advertised-pair counts, multipath fraction)
        # would silently describe a different topology
        raise ValueError(
            f"wan_2000km(dcs={dcs}) could only place {placed} of {chords} "
            "requested chords (distinct {2,3,dcs/2}-offset slots exhausted); "
            "lower chords= or raise dcs=")

    # expand each haul into `segs` spans through dedicated segment nodes;
    # _bidir emits (fwd, rev) per span, so a haul's first directed link
    # (the one schedules target) is at index 2 * (its first span's row)
    edges: List[Link] = []
    next_node = dcs
    main_first: List[int] = []
    for h, (a, b, cap, dl) in enumerate(hauls):
        seg_delay = max(dl // segs, 1)
        nodes = [a] + [next_node + j for j in range(segs - 1)] + [b]
        next_node += segs - 1
        if h < len(main):
            main_first.append(2 * len(edges))
        for u, v in zip(nodes[:-1], nodes[1:]):
            edges.append((u, v, cap, seg_delay))
    t = Topology(f"wan-2000km-{dcs}dc-{segs}seg-s{seed}", next_node,
                 _bidir(edges))
    return WanWorld(topology=t, main_pair=(0, 1),
                    dc_nodes=tuple(range(dcs)),
                    main_haul_links=tuple(main_first))


# --------------------------------------------- geography-grounded WAN (geo)
# Great-circle math + a planetary DC ring: the wan_2000km generator with
# *declared* delay classes replaced by delays derived from real DC-metro
# coordinates at fiber propagation speed. Long-haul fiber carries light at
# ~0.67c (group index ~1.47), i.e. ~0.2009 km/us — the constant every WAN
# RTT rule-of-thumb (~1 ms per 100 km one-way) comes from.
EARTH_RADIUS_KM = 6371.0
FIBER_KM_PER_US = 0.299792458 * 0.67          # ~0.2009 km/us at 0.67c
GEO_SPAN_KM = 2000.0                          # OTN span class (wan2000's)
# fiber routes are never great circles: declared route-stretch factors,
# one per parallel main-pair haul (fat haul gets the direct route, the
# thin ones progressively longer detour fibers — the testbed's
# fast-fat/slow-thin heterogeneity, now geographically motivated) and one
# for every ordinary ring/chord haul.
GEO_MAIN_STRETCH = (1.0, 1.25, 1.5)
GEO_RING_STRETCH = 1.1
GEO_MAIN_CAPS = (200, 100, 40)                # Gbps, fattest first

# DC metros: (name, lat, lon, metro population in millions). geo_wan
# selects the first ``dcs`` entries, then ring-orders them by longitude
# (the natural planetary ring). Populations drive the traffic-matrix
# weights (traffic/sched.py), coordinates drive haul delays and the
# diurnal timezone phase (longitude / 15 deg per hour).
GEO_DCS = (
    ("tokyo", 35.6762, 139.6503, 37.0),
    ("delhi", 28.7041, 77.1025, 32.0),
    ("shanghai", 31.2304, 121.4737, 28.0),
    ("saopaulo", -23.5505, -46.6333, 22.0),
    ("mexicocity", 19.4326, -99.1332, 22.0),
    ("dhaka", 23.8103, 90.4125, 22.0),
    ("cairo", 30.0444, 31.2357, 21.0),
    ("beijing", 39.9042, 116.4074, 21.0),
    ("mumbai", 19.0760, 72.8777, 21.0),
    ("osaka", 34.6937, 135.5023, 19.0),
    ("newyork", 40.7128, -74.0060, 19.0),
    ("karachi", 24.8607, 67.0011, 16.0),
    ("buenosaires", -34.6037, -58.3816, 15.0),
    ("istanbul", 41.0082, 28.9784, 15.0),
    ("lagos", 6.5244, 3.3792, 15.0),
    ("london", 51.5074, -0.1278, 14.0),
    ("losangeles", 34.0522, -118.2437, 13.0),
    ("paris", 48.8566, 2.3522, 11.0),
    ("johannesburg", -26.2041, 28.0473, 6.0),
    ("singapore", 1.3521, 103.8198, 6.0),
    ("sydney", -33.8688, 151.2093, 5.0),
    ("seattle", 47.6062, -122.3321, 4.0),
    ("frankfurt", 50.1109, 8.6821, 2.7),
    ("dublin", 53.3498, -6.2603, 1.4),
)


def geodesic_km(lat1, lon1, lat2, lon2):
    """Haversine great-circle distance in km (scalars or numpy arrays)."""
    la1, lo1, la2, lo2 = (np.radians(np.asarray(x, np.float64))
                          for x in (lat1, lon1, lat2, lon2))
    h = (np.sin((la2 - la1) / 2.0) ** 2
         + np.cos(la1) * np.cos(la2) * np.sin((lo2 - lo1) / 2.0) ** 2)
    return 2.0 * EARTH_RADIUS_KM * np.arcsin(np.sqrt(np.clip(h, 0.0, 1.0)))


def fiber_delay_us(dist_km: float, stretch: float = 1.0) -> int:
    """One-way propagation delay of a fiber route ``stretch`` x the
    geodesic, at ~0.67c. Floors at 1 us (metro-adjacent DCs)."""
    return max(int(round(dist_km * stretch / FIBER_KM_PER_US)), 1)


def geo_spans(dist_km: float, stretch: float = 1.0,
              max_spans: int = 4) -> int:
    """Number of 2000 km-class OTN spans a haul of this route length is
    chained from (amplifier/regenerator sites), capped so candidate
    enumeration hop budgets stay bounded — a capped haul just has
    longer-than-class spans."""
    return int(np.clip(np.ceil(dist_km * stretch / GEO_SPAN_KM),
                       1, max_spans))


@dataclasses.dataclass(frozen=True)
class GeoWorld:
    """A geography-grounded WAN plus the metadata the scenario and
    traffic-schedule layers need (same role as WanWorld, with
    coordinates/populations attached)."""
    topology: Topology
    main_pair: Tuple[int, int]
    dc_nodes: Tuple[int, ...]
    main_haul_links: Tuple[int, ...]  # first directed link per main haul
    dc_name: Tuple[str, ...]
    dc_lat: Tuple[float, ...]
    dc_lon: Tuple[float, ...]
    dc_pop: Tuple[float, ...]        # millions (traffic-matrix weights)
    max_spans: int                   # per-haul span cap (hop budgets)


def geo_wan(dcs: int = 20, chords: int = 10, seed: int = 0,
            max_spans: int = 4) -> GeoWorld:
    """Planetary WAN grounded in real geography: the first ``dcs``
    entries of ``GEO_DCS`` ring-ordered by longitude, ring hauls between
    longitude neighbors plus ``chords`` random shortcut hauls, every haul
    delay derived from the geodesic distance at ~0.67c (``stretch`` x
    for fiber-route detour) and chained from 2000 km-class OTN spans
    (``geo_spans``). The main pair is the ring edge with the largest
    population product, given three parallel hauls (200/100/40 Gbps at
    progressively longer fiber routes — fast-fat/slow-thin). Capacities
    still come from ``WAN_CAP_CLASSES``; *delays* are geography.

    Deterministic under ``(dcs, chords, seed)``.
    """
    if not 4 <= dcs <= len(GEO_DCS):
        raise ValueError(f"geo_wan needs 4 <= dcs <= {len(GEO_DCS)}, "
                         f"got {dcs}")
    sel = sorted(GEO_DCS[:dcs], key=lambda c: c[2])   # ring by longitude
    names = tuple(c[0] for c in sel)
    lat = tuple(float(c[1]) for c in sel)
    lon = tuple(float(c[2]) for c in sel)
    pop = tuple(float(c[3]) for c in sel)

    def dist(a: int, b: int) -> float:
        return float(geodesic_km(lat[a], lon[a], lat[b], lon[b]))

    # main pair: the ring edge with the largest population product
    ring = [(i, (i + 1) % dcs) for i in range(dcs)]
    ma, mb = max(ring, key=lambda e: pop[e[0]] * pop[e[1]])

    rng = np.random.default_rng(seed)
    # hauls: (a, b, cap_gbps, one_way_delay_us, spans)
    hauls = []
    d_main = dist(ma, mb)
    for cap, stretch in zip(GEO_MAIN_CAPS, GEO_MAIN_STRETCH):
        hauls.append((ma, mb, cap, fiber_delay_us(d_main, stretch),
                      geo_spans(d_main, stretch, max_spans)))
    for a, b in ring:
        if (a, b) == (ma, mb):
            continue
        d = dist(a, b)
        hauls.append((a, b, int(rng.choice(WAN_CAP_CLASSES)),
                      fiber_delay_us(d, GEO_RING_STRETCH),
                      geo_spans(d, GEO_RING_STRETCH, max_spans)))
    seen = {(a, b) for a, b, *_ in hauls}
    placed, tries = 0, 0
    while placed < chords and tries < 20 * chords:
        tries += 1
        a = int(rng.integers(0, dcs))
        off = int(rng.choice([2, 3, max(dcs // 2, 4)]))
        b = (a + off) % dcs
        if a == b or (a, b) in seen or (b, a) in seen:
            continue
        seen.add((a, b))
        d = dist(a, b)
        hauls.append((a, b, int(rng.choice(WAN_CAP_CLASSES)),
                      fiber_delay_us(d, GEO_RING_STRETCH),
                      geo_spans(d, GEO_RING_STRETCH, max_spans)))
        placed += 1
    if placed < chords:
        raise ValueError(
            f"geo_wan(dcs={dcs}) could only place {placed} of {chords} "
            "requested chords; lower chords= or raise dcs=")

    # expand hauls into spans through dedicated segment nodes (the
    # wan_2000km construction: a haul's first directed link index is
    # 2 * its first span's row, _bidir interleaves fwd/rev)
    edges: List[Link] = []
    next_node = dcs
    main_first: List[int] = []
    for h, (a, b, cap, dl, segs) in enumerate(hauls):
        seg_delay = max(dl // segs, 1)
        nodes = [a] + [next_node + j for j in range(segs - 1)] + [b]
        next_node += segs - 1
        if h < len(GEO_MAIN_CAPS):
            main_first.append(2 * len(edges))
        for u, v in zip(nodes[:-1], nodes[1:]):
            edges.append((u, v, cap, seg_delay))
    t = Topology(f"geo-{dcs}dc-s{seed}", next_node, _bidir(edges))
    return GeoWorld(topology=t, main_pair=(ma, mb),
                    dc_nodes=tuple(range(dcs)),
                    main_haul_links=tuple(main_first),
                    dc_name=names, dc_lat=lat, dc_lon=lon, dc_pop=pop,
                    max_spans=max_spans)


def delay_jitter(base: Topology, frac: float = 0.2, seed: int = 0) -> Topology:
    """Apply asymmetric delay jitter: every *directed* link's propagation
    delay is independently scaled by U[1-frac, 1+frac], so forward and
    reverse directions of the same fiber diverge — the delay-asymmetry
    regime long-haul RTT estimators (and the paper's delayScore) must
    tolerate."""
    rng = np.random.default_rng(seed)
    links = [(s, d, c, max(int(round(dl * (1.0 + frac * (2.0 * rng.random() - 1.0)))), 1))
             for (s, d, c, dl) in base.links]
    return Topology(f"{base.name}-jitter{frac}s{seed}", base.num_nodes, links)


def parallel_paths(caps=(100, 100), delays_us=(5000, 5000)) -> Topology:
    """src=0, dst=N+1, one transit node per parallel path — the minimal
    multi-path fixture for routing tests."""
    edges: List[Link] = []
    n = len(caps)
    for i, (c, d) in enumerate(zip(caps, delays_us)):
        edges.append((0, 1 + i, c, d))
        edges.append((1 + i, n + 1, 400, 1000))
    return Topology("parallel", n + 2, _bidir(edges))
