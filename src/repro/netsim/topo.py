"""Inter-DC topologies used in the paper's evaluation (§6, Fig. 4).

A topology is a small directed graph of DCI switches: ``links[i] =
(src, dst, cap_gbps, delay_us)``. Intra-DC fabrics are abstracted away —
the paper provisions them (100G leaf-spine, 400G DCI uplinks) precisely
so they are never the bottleneck; all placement dynamics happen on the
inter-DC links, which is what we model.

Provided:
- ``testbed_8dc``    : Fig. 1a / §6.1 — DC1..DC8, six candidate routes
  DC1->DC8 through DC2..DC7 with {200,200,100,100,40,40} Gbps long-haul
  links, one low-delay (5 ms) and one high-delay (250 ms) member per
  capacity class, and fat 400 Gbps / 1 ms tail hops so the long-haul link
  defines each path.
- ``bso_13dc``       : §6.2 — a 13-DC European backbone in the style of
  BSONetworkSolutions (Internet Topology Zoo). The Zoo's exact edge list
  is not redistributable offline, so we build a structurally matched
  stand-in: 13 nodes, sparse ring+chord mesh, delays quantized to
  {1, 5, 10} ms (200/1000/2000 km) and heterogeneous 40-400 Gbps
  capacities, tuned so ~26% of node pairs see multiple first-hop-distinct
  candidate routes (paper: 20/78 = 25.6%).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

Link = Tuple[int, int, int, int]  # (src, dst, cap_gbps, delay_us)


@dataclasses.dataclass(frozen=True)
class Topology:
    name: str
    num_nodes: int
    links: List[Link]              # directed (both directions listed)

    @property
    def num_links(self) -> int:
        return len(self.links)

    def arrays(self):
        a = np.asarray(self.links, np.int64)
        return (a[:, 0].astype(np.int32), a[:, 1].astype(np.int32),
                a[:, 2].astype(np.int32), a[:, 3].astype(np.int32))


def _bidir(edges: List[Link]) -> List[Link]:
    out: List[Link] = []
    for s, d, c, dl in edges:
        out.append((s, d, c, dl))
        out.append((d, s, c, dl))
    return out


def testbed_8dc() -> Topology:
    """Fig. 1a. Nodes 0..7 = DC1..DC8. Six 2-hop routes DC1->DC8."""
    ms = 1000
    # (transit DC, long-haul capacity Gbps, long-haul one-way delay us)
    # Delays span the paper's stated 5-250 ms range with one low-delay and
    # one high-delay member per capacity class. The intermediate values
    # (25/35 ms) matter: they put the 4th-cheapest path within beta*255
    # fused-cost points of the kept set, so the congestion term can swap a
    # hot low-delay path out — the adaptivity the paper's ablation
    # (rm-beta "fails for large transfers") demonstrates. All-extreme
    # delays (5 vs 250 only) would make the kept set static under (3,1).
    classes = [
        (1, 200, 250 * ms),   # DC2: high-capacity, high-delay
        (2, 200, 25 * ms),    # DC3: high-capacity, low-delay
        (3, 100, 35 * ms),    # DC4: medium, higher-delay
        (4, 100, 5 * ms),     # DC5: medium, low-delay
        (5, 40, 5 * ms),      # DC6: low, low-delay
        (6, 40, 250 * ms),    # DC7: low, high-delay
    ]
    edges: List[Link] = []
    for dc, cap, delay in classes:
        edges.append((0, dc, cap, delay))      # DC1 -> transit (long haul)
        edges.append((dc, 7, 400, 1 * ms))     # transit -> DC8 (fat tail hop)
    return Topology("testbed-8dc", 8, _bidir(edges))


def bso_13dc() -> Topology:
    """13-DC European backbone stand-in (BSONetworkSolutions style).

    Delay tiers: 1 ms (~200 km), 5 ms (~1000 km), 10 ms (~2000 km).
    Mixed 40-400 Gbps provisioning; sparse enough that only a quarter of
    pairs are truly multi-path (paper §6.2: gains dilute system-wide).
    """
    ms = 1000
    edges: List[Link] = [
        # core western-European ring
        (0, 1, 200, 1 * ms), (1, 2, 200, 1 * ms), (2, 3, 100, 5 * ms),
        (3, 4, 100, 1 * ms), (4, 5, 200, 5 * ms), (5, 6, 100, 1 * ms),
        (6, 7, 100, 5 * ms), (7, 8, 40, 1 * ms), (8, 9, 100, 5 * ms),
        (9, 10, 200, 1 * ms), (10, 11, 40, 5 * ms), (11, 12, 100, 1 * ms),
        (12, 0, 200, 10 * ms),
        # long-haul chords (2000 km class) creating multi-path pairs;
        # this set yields 26.3% multi-path pairs (paper: 20/78 = 25.6%)
        (0, 4, 400, 10 * ms), (2, 6, 40, 10 * ms), (5, 12, 100, 10 * ms),
    ]
    return Topology("bso-13dc", 13, _bidir(edges))


def duplex_line(num_nodes: int = 3, cap: int = 100, delay_us: int = 5000) -> Topology:
    """Tiny chain for unit tests."""
    edges = [(i, i + 1, cap, delay_us) for i in range(num_nodes - 1)]
    return Topology("line", num_nodes, _bidir(edges))


def segmented_parallel(route_caps, route_delays_us, segs: int = 2,
                       tail_cap: int = 400, tail_delay_us: int = 1000) -> Topology:
    """Parallel long-haul routes where each route's long haul is a chain of
    ``segs`` OTN segments in series (MatchRDMA-style segmented links: a
    2000 km haul is really several amplified/regenerated spans, and a
    single span can fail or degrade independently).

    Node layout: 0 = src DC, then ``segs`` transit nodes per route, then
    dst = 1 + len(routes)*segs. Route i gets capacity ``route_caps[i]`` on
    every segment and its one-way delay ``route_delays_us[i]`` split evenly
    across segments, followed by a fat tail hop into the destination (the
    same "long haul defines the path" construction as the 8-DC testbed).

    With the default ``MAX_HOPS=5`` path enumeration, ``segs`` must stay
    <= 4 (segs long-haul hops + 1 tail hop per route).
    """
    n = len(route_caps)
    assert len(route_delays_us) == n
    if not 1 <= segs <= 4:   # paths.MAX_HOPS=5 minus the tail hop
        raise ValueError(f"segs={segs} unroutable: paths are segs+1 hops "
                         "and candidate enumeration caps at 5 (paths.MAX_HOPS)")
    dst = 1 + n * segs
    edges: List[Link] = []
    for i, (cap, delay) in enumerate(zip(route_caps, route_delays_us)):
        seg_delay = max(int(delay) // segs, 1)
        nodes = [0] + [1 + i * segs + j for j in range(segs)]
        for a, b in zip(nodes[:-1], nodes[1:]):
            edges.append((a, b, int(cap), seg_delay))
        edges.append((nodes[-1], dst, tail_cap, tail_delay_us))
    return Topology(f"segmented-parallel-{n}x{segs}", dst + 1, _bidir(edges))


def delay_jitter(base: Topology, frac: float = 0.2, seed: int = 0) -> Topology:
    """Apply asymmetric delay jitter: every *directed* link's propagation
    delay is independently scaled by U[1-frac, 1+frac], so forward and
    reverse directions of the same fiber diverge — the delay-asymmetry
    regime long-haul RTT estimators (and the paper's delayScore) must
    tolerate."""
    rng = np.random.default_rng(seed)
    links = [(s, d, c, max(int(round(dl * (1.0 + frac * (2.0 * rng.random() - 1.0)))), 1))
             for (s, d, c, dl) in base.links]
    return Topology(f"{base.name}-jitter{frac}s{seed}", base.num_nodes, links)


def parallel_paths(caps=(100, 100), delays_us=(5000, 5000)) -> Topology:
    """src=0, dst=N+1, one transit node per parallel path — the minimal
    multi-path fixture for routing tests."""
    edges: List[Link] = []
    n = len(caps)
    for i, (c, d) in enumerate(zip(caps, delays_us)):
        edges.append((0, 1 + i, c, d))
        edges.append((1 + i, n + 1, 400, 1000))
    return Topology("parallel", n + 2, _bidir(edges))
