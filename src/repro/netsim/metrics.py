"""FCT-slowdown and utilization metrics (paper §6 "Metrics").

Slowdown = actual FCT / ideal FCT, where the ideal FCT is the flow run
alone on the pair's minimum-propagation-delay candidate path: ideal =
prop(best) + size / bottleneck_cap(best)  (queueing isolated by
construction, exactly the paper's definition).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

from repro.netsim import sanitize
from repro.netsim.engine import SimArrays, SimConfig, SimState
from repro.netsim.paths import PathTable
from repro.traffic.gen import FlowSet


@dataclasses.dataclass
class FCTStats:
    slowdown: np.ndarray     # (F_done,)
    sizes: np.ndarray        # (F_done,)
    completed: int
    offered: int

    @property
    def completion_rate(self) -> float:
        """completed/offered — the survivorship-bias guard. Slowdown
        percentiles are over completed flows only, so a policy that
        strands flows past the horizon "wins" p99 unless every consumer
        checks this alongside (benchmarks plumb it into every CSV row)."""
        return self.completed / self.offered if self.offered else float("nan")

    def pct(self, q: float) -> float:
        return float(np.percentile(self.slowdown, q)) if len(self.slowdown) else float("nan")

    @property
    def p50(self) -> float:
        return self.pct(50)

    @property
    def p99(self) -> float:
        return self.pct(99)

    def by_size_bucket(self, edges) -> Dict[str, Dict[str, float]]:
        out = {}
        for lo, hi in zip(edges[:-1], edges[1:]):
            m = (self.sizes >= lo) & (self.sizes < hi)
            if m.sum() >= 5:
                s = self.slowdown[m]
                out[f"{int(lo)}-{int(hi)}"] = {
                    "p50": float(np.percentile(s, 50)),
                    "p99": float(np.percentile(s, 99)),
                    "n": int(m.sum()),
                }
        return out


def _collapse_subflows(flows: FlowSet, done, fct, mask):
    """Aggregate per-subflow sim rows back to parent flows (amp): a
    parent is done when ALL its subflows delivered, its FCT is the LAST
    subflow's, its size/ideal use the summed bytes. ``mask`` (and
    ``pair_id``/``fg``) are uniform within a parent by construction, so
    any subflow's value represents the parent."""
    sof = np.asarray(flows.subflow_of)
    n = int(sof.max()) + 1 if len(sof) else 0
    done_p = np.ones(n, bool)
    np.logical_and.at(done_p, sof, done)
    fct_p = np.zeros(n, np.float64)
    np.maximum.at(fct_p, sof, np.where(done, fct, 0.0))
    size_p = np.zeros(n, np.float64)
    np.add.at(size_p, sof, flows.size_bytes)
    pair_p = np.zeros(n, np.int32)
    pair_p[sof] = flows.pair_id
    mask_p = None
    if mask is not None:
        mask_p = np.zeros(n, bool)
        mask_p[sof] = np.asarray(mask)
        done_p = done_p & mask_p
    return done_p, fct_p, size_p, pair_p, mask_p


def fct_stats(final: SimState, table: PathTable, flows: FlowSet,
              cfg: SimConfig, mask=None) -> FCTStats:
    """Slowdown stats over all flows, or the ``mask``-selected subset
    (e.g. ``flows.foreground`` for the measured pairs only). Subflow
    sets (``flows.subflow_of``) are scored at the parent level:
    last-subflow completion time over the parent's full byte count."""
    done = np.asarray(final.done)
    fct = np.asarray(final.fct_us)
    sizes = flows.size_bytes
    pair = flows.pair_id
    if getattr(flows, "subflow_of", None) is not None:
        done, fct, sizes, pair, mask = _collapse_subflows(
            flows, done, fct, mask)
    elif mask is not None:
        done = done & mask
    prop = table.pair_ideal_prop[pair].astype(np.float64)
    cap = table.pair_ideal_cap[pair] * 125.0 * cfg.cap_scale
    ideal = prop + sizes / cap
    sl = fct[done] / ideal[done]
    offered = int(mask.sum()) if mask is not None else len(done)
    if sanitize.host_checks_enabled():
        # completion-accounting identity (host-side half of the
        # completion_identity invariant)
        sanitize.host_check(int(done.sum()) <= offered,
                            "completion_identity: more completions than "
                            "offered flows")
        sanitize.host_check(bool((fct[done] > 0.0).all()),
                            "completion_identity: completed flow with "
                            "FCT <= 0")
        sanitize.host_check(bool(np.isfinite(sl).all()),
                            "completion_identity: non-finite slowdown")
    return FCTStats(slowdown=np.maximum(sl, 1.0), sizes=sizes[done],
                    completed=int(done.sum()), offered=offered)


def completion_wall_us(final: SimState, flows: FlowSet) -> np.ndarray:
    """(F,) wall-clock completion time per flow row: arrival plus the
    engine's FCT *duration*; NaN where the flow never delivered. The
    barrier primitive ``repro.cosim.iterate`` builds iteration makespans
    from (an iteration ends at the max wall completion of its buckets,
    not at the max duration — late-arriving fast buckets still gate)."""
    done = np.asarray(final.done)
    wall = np.asarray(flows.arrival_us, np.float64) + np.asarray(final.fct_us)
    return np.where(done, wall, np.nan)


def fg_bg_stats(final: SimState, table: PathTable, flows: FlowSet,
                cfg: SimConfig, overall: FCTStats = None):
    """(foreground, background) FCTStats — the measured pairs vs the
    cross-traffic. ``background`` is None when everything is foreground
    (no ``bg_load`` was dosed); pass already-computed whole-set stats as
    ``overall`` to reuse them for that case instead of recomputing."""
    fg = flows.foreground
    if fg.all():
        return (overall if overall is not None
                else fct_stats(final, table, flows, cfg)), None
    return (fct_stats(final, table, flows, cfg, mask=fg),
            fct_stats(final, table, flows, cfg, mask=~fg))


def phase_stats(final: SimState, table: PathTable, flows: FlowSet,
                cfg: SimConfig, sched_t, seg_phase,
                mask=None) -> Dict[str, FCTStats]:
    """FCTStats per *schedule phase* for time-varying load runs
    (``ExpSpec.load_sched``): each flow belongs to the schedule segment
    its arrival falls in (the ``gen._poisson_sched`` mapping), and
    ``seg_phase[k]`` labels segment ``k`` — e.g. ``"peak"`` /
    ``"offpeak"`` / ``"crossover"`` from the measured pair's diurnal
    row. Returns one FCTStats per distinct label, in first-appearance
    order; compose with ``mask=flows.foreground`` to phase-split just
    the measured pairs. This is the per-phase breakdown fig_geo
    reports — a policy must track the cycle, not win one steady state.
    """
    sched_t = np.asarray(sched_t, np.int64)
    seg_phase = list(seg_phase)
    if len(seg_phase) != len(sched_t):
        raise ValueError(f"seg_phase must label all {len(sched_t)} "
                         f"segments, got {len(seg_phase)}")
    seg = np.searchsorted(sched_t, np.asarray(flows.arrival_us),
                          side="right") - 1
    out: Dict[str, FCTStats] = {}
    for ph in dict.fromkeys(seg_phase):
        in_ph = np.isin(seg, [k for k, p in enumerate(seg_phase)
                              if p == ph])
        if mask is not None:
            in_ph = in_ph & mask
        out[ph] = fct_stats(final, table, flows, cfg, mask=in_ph)
    return out


def per_pair_stats(final: SimState, table: PathTable, flows: FlowSet,
                   cfg: SimConfig) -> Dict[int, FCTStats]:
    """FCTStats per traffic pair (keys: pair ids present in the flow
    set) — the large-WAN per-pair breakdown: a policy must not win the
    aggregate by starving individual pairs."""
    out: Dict[int, FCTStats] = {}
    for pid in np.unique(flows.pair_id):
        out[int(pid)] = fct_stats(final, table, flows, cfg,
                                  mask=flows.pair_id == pid)
    return out


def link_utilization(final: SimState, arrs: SimArrays, cfg: SimConfig) -> np.ndarray:
    """Average served utilization per link over the horizon (Fig. 1b).

    Normalized by the *effective* capacity-time integral: the fail and
    degrade schedules are applied step-wise exactly as the simulator
    applies them, so a link degraded to 25% that serves 25% of nominal
    reports ~1.0 (saturated), not a misleading 0.25."""
    T = cfg.num_steps
    cap = np.asarray(arrs.link_cap, np.float64)
    eff_steps = np.float64(T)
    if arrs.link_fail_step is not None:
        # sim semantics: alive while t < fail_step; degraded from
        # t >= deg_step — full-cap steps then factor-cap steps while alive
        alive = np.clip(np.asarray(arrs.link_fail_step, np.int64), 0, T)
        deg = np.clip(np.asarray(arrs.link_deg_step, np.int64), 0, T)
        full = np.minimum(alive, deg)
        fac = np.asarray(arrs.link_deg_factor, np.float64)
        eff_steps = full + fac * np.maximum(alive - full, 0)
    cap_total = cap * eff_steps * cfg.dt_us
    return np.asarray(final.serv_bytes) / np.maximum(cap_total, 1e-9)
