"""Shared multi-engine simulation core (the surface both the fluid-rate
and the packet-level engines consume).

The repo ships two simulation backends behind one interface:

- ``repro.netsim.fluid``  — flow-level fluid-rate approximation (fast;
  max-min link sharing, analytic queue integration);
- ``repro.netsim.packet`` — slotted packet-level engine (the NS-3
  analogue of paper §6: per-hop FIFO byte/packet queues, ECN marking
  thresholds, PFC pause/resume with backward propagation delay, windowed
  sources).

Both engines are one jitted ``lax.scan`` over ``SimState`` and share,
*by construction* (same functions, not parallel implementations):

- ``SimConfig`` / ``SimArrays`` / ``SimState`` — the experiment config,
  static device arrays, and the dynamic pytree (the packet engine
  subclasses ``SimState`` with its extra per-hop queue state);
- ``build()`` — tables, arrival bucketing, failure/degradation schedule
  folding, signal-delay precomputation, HIST validation;
- the **signal plane**: the ``core.cong`` register pipeline recorded per
  step in the ``hist_c`` ring (``monitor_tick``), read back with
  backward propagation delay (``path_cong_view``);
- the **control plane**: periodic ``C_path`` re-install from effective
  capacities (``ctrl_refresh`` / ``ctrl_tick``);
- **routing**: arrival-time decisions through ``select.select_egress``
  and the baselines, flow stickiness, and lazy failover
  (``_route_arrivals`` / ``_reroute_dead``);
- the **CC rate laws** (``_cc_update``): DCQCN/DCTCP/TIMELY/HPCC-like,
  reacting to RTT-delayed signals from the ``hist_q``/``hist_u`` rings —
  the fluid engine uses the rate directly, the packet engine paces
  packet injection with it and bounds in-flight bytes by the rate-BDP
  window.

An *engine* is any module satisfying the ``Engine`` protocol below
(``name`` / ``build`` / ``run_impl`` / ``run``); ``get_engine`` resolves
the ``SimConfig.engine`` / ``ExpSpec.engine`` string. Final states feed
``metrics.fct_stats`` unchanged — every scenario, sweep axis, and figure
grid runs on either backend.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as bl
from repro.core import cong as congmod
from repro.core import select as selmod
from repro.core.cong import CongParams, CongState
from repro.core.pathq import (PathQParams, calc_path_quality,
                              path_bottleneck_stats)
from repro.core.select import SelectParams
from repro.core.tables import CELL_BYTES, bootstrap_tables
from repro.netsim.paths import PathTable
from repro.traffic.gen import FlowSet

HIST = 8192          # history rings (steps); must exceed the max RTT and
                     # signal-delay offsets — build() validates this

# Every history-ring scatter index is `t % HIST`, in-bounds by
# construction, so the write sites state that instead of inheriting the
# default FILL_OR_DROP (which would silently drop an out-of-bounds write
# if the wrap ever regressed). Tests flip this to None to pin that both
# modes are bit-identical for in-bounds indices.
RING_SCATTER_MODE = "promise_in_bounds"

# Policy name -> dense code. "sweep" is a meta-policy: the step function
# dispatches on the per-experiment ``SimArrays.policy_code`` scalar instead
# of a Python branch, so a vmapped batch can mix policies in one trace
# (the sweep engine's whole-grid-single-XLA-computation mode).
#
# The mapping is FROZEN — codes leak into trace keys, CSV rows and
# ``SimArrays.policy_code``, so new policies may only append fresh codes,
# never renumber existing ones (pinned by tests/test_redecision.py).
POLICY_CODES = {
    "lcmp": 0,       # paper §3-§5: cost + congestion two-stage select
    "lcmp_w": 1,     # beyond-paper: capacity-weighted stage-2 hash
    "ecmp": 2,
    "ucmp": 3,
    "wcmp": 4,
    "redte": 5,
    "fatpaths": 6,   # layered min-stretch routing + flowlet re-hash
    "amp": 7,        # multi-subflow transport (per-subflow ECMP hash)
    "lcmp_r": 8,     # ablation: LCMP with periodic mid-flow re-decision
    "matchrdma": 9,  # segmented per-span rate matching on OTN hauls
}
POLICIES = tuple(POLICY_CODES)
# policies whose law re-decides mid-flow when the engine's eligibility
# trigger fires (flowlet idle gap / re-decision epoch)
REDECIDE_POLICIES = ("fatpaths", "lcmp_r")
ENGINES = ("fluid", "packet")
_NEVER = (1 << 30)   # sentinel step for "this link never fails/degrades"


def policy_code(policy: str) -> int:
    if policy not in POLICY_CODES:
        raise ValueError(f"unknown policy {policy!r}; valid: {POLICIES}")
    return POLICY_CODES[policy]


@runtime_checkable
class Engine(Protocol):
    """What a simulation backend must provide (modules satisfy this)."""
    name: str

    def build(self, table: PathTable, flows: FlowSet, cfg: "SimConfig"):
        """Pack tables + flows -> (SimArrays, SimState-like pytree)."""

    def run_impl(self, arrs: "SimArrays", state, cfg: "SimConfig"):
        """Unjitted scan body (the sweep engine vmaps this)."""

    def run(self, arrs: "SimArrays", state, cfg: "SimConfig"):
        """Jitted single-experiment entry point -> final state."""


def get_engine(name: str) -> Engine:
    """Resolve an engine string (``SimConfig.engine``) to its module."""
    if name == "fluid":
        from repro.netsim import fluid
        return fluid
    if name == "packet":
        from repro.netsim import packet
        return packet
    raise ValueError(f"unknown engine {name!r}; valid: {ENGINES}")


@dataclasses.dataclass(frozen=True)
class SimConfig:
    engine: str = "fluid"         # fluid|packet (see get_engine)
    policy: str = "lcmp"          # lcmp|ecmp|ucmp|wcmp|redte|sweep
    cc: str = "dcqcn"             # dcqcn|dctcp|timely|hpcc
    dt_us: int = 200
    horizon_us: int = 2_000_000
    cap_scale: float = 0.125      # uniform capacity scale (sim speed knob)
    buffer_bytes: float = 6e9     # long-haul switch buffer (paper §6.2)
    ecn_kmin_bytes: float = 4e5   # ECN mark threshold Kmin (scaled caps)
    ecn_kmax_factor: float = 10.0  # Kmax = factor * Kmin (RED ramp top)
    ai_frac: float = 0.002        # additive increase per step, frac of line
    md_factor: float = 0.7        # multiplicative decrease
    # MD reaction timer (us): real DCQCN/TIMELY decrease on a NIC timer,
    # not once per RTT — on a 250 ms long-haul path a per-RTT gate would
    # leave flows effectively uncontrolled. Feedback *delay* stays RTT.
    cc_dec_period_us: int = 1_600
    redte_period_us: int = 100_000
    # routing-signal staleness: each hop's C_cong reaches the ingress
    # after scale x its one-way propagation distance back (1.0 = physics;
    # 0.0 = oracle visibility; >1 models slower telemetry channels)
    sig_delay_scale: float = 1.0
    # control-plane C_path re-install period (paper §7.3); 0 = never
    # refresh (the build-time static table)
    ctrl_period_us: int = 100_000
    # ---- packet-engine knobs (ignored by the fluid engine) ----
    mtu_bytes: int = 1024         # packet size; == CELL_BYTES so queue
                                  # depth in packets == monitor cells
    # PFC pause/resume hysteresis as fractions of the (scaled) buffer:
    # XOFF fires above, XON releases below. The pause frame reaches the
    # upstream transmitter one backward link propagation late, so queues
    # overshoot XOFF by up to rate x delay — the long-haul headroom
    # problem the paper's 6 GB buffers exist for.
    pfc_xoff_frac: float = 0.7
    pfc_xon_frac: float = 0.5
    select: SelectParams = SelectParams()
    pathq: PathQParams = PathQParams()
    congp: CongParams = CongParams()
    # optional single-link failure injection (legacy single-event form;
    # folded into the schedule arrays at build time)
    fail_link: int = -1
    fail_at_us: int = -1
    # scenario schedules (hashable static tuples, see netsim.scenarios):
    # fail_sched    = ((link_idx, at_us), ...)          hard link trips
    # degrade_sched = ((link_idx, at_us, factor), ...)  silent capacity loss
    fail_sched: tuple = ()
    degrade_sched: tuple = ()
    # policy=="sweep" only: the policies the dynamic dispatch must cover.
    # The sweep engine narrows this to the ones actually present in a
    # batch so un-swept policies cost nothing per step.
    sweep_policies: tuple = POLICIES
    # ---- mid-flow re-decision plane (REDECIDE_POLICIES only) ----
    # Eligibility is engine-specific: the packet engine re-hashes a flow
    # whose queues drained for >= flowlet_gap_us (FatPaths flowlet
    # switching — observable idle gaps exist only where packets do); the
    # fluid engine re-decides on a redecide_period_us timer epoch. 0
    # disables the plane for that engine and keeps the step bit-identical
    # to pinned-path routing (asserted in tests).
    flowlet_gap_us: int = 0
    redecide_period_us: int = 0
    # amp only: subflows per flow (traffic/gen.py splits sizes; metrics
    # scores the parent flow at last-subflow completion)
    n_subflows: int = 1
    # debug mode: thread the checkify physics-invariant sanitizer
    # (repro.netsim.sanitize) through the scan. Static, so the unchecked
    # program is bit-for-bit untouched when False (asserted in tests).
    checks: bool = False

    @property
    def num_steps(self) -> int:
        return self.horizon_us // self.dt_us

    @property
    def has_failures(self) -> bool:
        return self.fail_link >= 0 or len(self.fail_sched) > 0

    @property
    def has_degrade(self) -> bool:
        return len(self.degrade_sched) > 0


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SimState:
    # per flow
    flow_path: jnp.ndarray     # (F,) i32, -1 until routed
    remaining: jnp.ndarray     # (F,) f32 bytes
    rate: jnp.ndarray          # (F,) f32 bytes/us
    active: jnp.ndarray        # (F,) bool
    done: jnp.ndarray          # (F,) bool
    fct_us: jnp.ndarray        # (F,) f32
    extra_wait: jnp.ndarray    # (F,) f32 queue-wait component
    rtt_steps: jnp.ndarray     # (F,) i32
    route_step: jnp.ndarray    # (F,) i32 step the flow was (re)routed at
    route_nonce: jnp.ndarray   # (F,) i32 re-decision counter (salts the
                               # flow's hash key per flowlet/epoch)
    last_dec: jnp.ndarray      # (F,) i32 step of last MD
    cc_alpha: jnp.ndarray      # (F,) f32 (DCTCP EWMA)
    cc_target: jnp.ndarray     # (F,) f32 (DCQCN target rate / fast recovery)
    prev_delay: jnp.ndarray    # (F,) f32 (TIMELY gradient)
    # per link
    q_bytes: jnp.ndarray       # (L,) f32
    hist_q: jnp.ndarray        # (L, HIST) f32 queue bytes
    hist_u: jnp.ndarray        # (L, HIST) f32 utilization
    hist_c: jnp.ndarray        # (L, HIST) i32 quantized C_cong per step
    u_ewma: jnp.ndarray        # (L,) f32
    link_alive: jnp.ndarray    # (L,) bool
    serv_bytes: jnp.ndarray    # (L,) f32 served-byte counter (metrics)
    cong: CongState            # LCMP per-link registers
    c_cong: jnp.ndarray        # (L,) i32 current LCMP congestion score
    # control-plane installed path scores — *state*, periodically
    # re-installed from effective capacities (see ``ctrl_refresh``)
    c_path: jnp.ndarray        # (NP,) i32
    redte_w: jnp.ndarray       # (NPAIR, K) i32 split weights


# SimState fields with a leading per-flow axis — the sweep engine pads
# and stacks exactly these when batching cells (the rest is per-link/
# per-pair and shape-shared across a group). Packet-engine extras are
# appended here so one list covers both state types; fields absent from
# a given state dataclass are simply never looked up.
FLOW_FIELDS = ("flow_path", "remaining", "rate", "active", "done", "fct_us",
               "extra_wait", "rtt_steps", "route_step", "route_nonce",
               "last_dec", "cc_alpha", "cc_target", "prev_delay",
               # packet engine (see packet.PacketState)
               "fq", "credit", "delivered", "last_tx")
# per-flow field -> inert pad value (mirrors build()'s init state)
STATE_PAD = {"flow_path": -1, "route_step": 1 << 20,
             "last_dec": -(1 << 20), "rtt_steps": 1, "last_tx": 1 << 20}


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimArrays:
    """Static (non-scanned) device arrays."""
    link_cap: jnp.ndarray      # (L,) f32 bytes/us (scaled)
    link_cap_gbps: jnp.ndarray # (L,) i32 (unscaled, for tables)
    path_links: jnp.ndarray    # (NP, H) i32
    path_prop: jnp.ndarray     # (NP,) i32 us
    path_cap: jnp.ndarray      # (NP,) f32 bytes/us (scaled bottleneck)
    path_cap_gbps: jnp.ndarray # (NP,) i32
    path_first: jnp.ndarray    # (NP,) i32
    pair_cand: jnp.ndarray     # (NPAIR, K) i32
    arrivals: jnp.ndarray      # (T, A) i32 flow idx, -1 pad
    f_arr_us: jnp.ndarray      # (F,) f32
    f_size: jnp.ndarray        # (F,) f32
    f_pair: jnp.ndarray        # (F,) i32
    f_id: jnp.ndarray          # (F,) u32
    # () i32 — read only when cfg.policy=="sweep"
    policy_code: jnp.ndarray = None
    link_fail_step: jnp.ndarray = None    # (L,) i32 trip step (_NEVER)
    link_deg_step: jnp.ndarray = None     # (L,) i32 degradation onset step
    link_deg_factor: jnp.ndarray = None   # (L,) f32 cap multiplier after onset
    path_len: jnp.ndarray = None          # (NP,) i32 valid hop count
    link_delay_us: jnp.ndarray = None     # (L,) i32 one-way propagation
    # (NP, H) i32 — steps each hop's congestion signal takes to propagate
    # back to the ingress (cumulative upstream one-way delay, scaled by
    # cfg.sig_delay_scale); hop 0 is the ingress's own egress port (0)
    path_sig_delay: jnp.ndarray = None
    tables: object = None      # SwitchTables


def build(table: PathTable, flows: FlowSet, cfg: SimConfig):
    """Pack numpy tables + flows into device arrays and init state.

    Engine-agnostic: returns the base ``SimState``; the packet engine
    wraps it with its extra per-hop queue fields (``packet.build``).
    """
    # links
    from repro.netsim.topo import Topology  # noqa: F401 (doc only)
    link_cap_gbps = _infer_link_caps(table)
    L = len(link_cap_gbps)
    link_cap = jnp.asarray(link_cap_gbps * 125.0 * cfg.cap_scale, jnp.float32)

    # the whole simulated world is capacity-scaled, so the switch tables
    # (trend normalization = cells per interval at line rate) and buffers
    # scale identically — timescales are then invariant under cap_scale.
    tb = bootstrap_tables([max(int(c * cfg.cap_scale), 1) for c in link_cap_gbps],
                          buffer_bytes=max(int(cfg.buffer_bytes * cfg.cap_scale),
                                           1 << 20),
                          sample_interval_us=cfg.dt_us)
    c_path = calc_path_quality(jnp.asarray(table.path_prop_us),
                               jnp.asarray(table.path_cap),
                               tb.cap_thresh, cfg.pathq)

    # per-path per-hop signal-propagation offsets: hop h's congestion
    # score travels back over hops 0..h-1, so the ingress sees it
    # sum(delay[0..h-1]) late (x sig_delay_scale)
    link_delay_us = _infer_link_delays(table)
    pl = np.asarray(table.path_links)
    hop_delay = np.where(pl >= 0, link_delay_us[np.maximum(pl, 0)], 0)
    upstream = np.concatenate([np.zeros((pl.shape[0], 1), np.int64),
                               np.cumsum(hop_delay, -1)[:, :-1]], axis=1)
    sig_delay_f = cfg.sig_delay_scale * upstream / cfg.dt_us
    sig_delay = sig_delay_f.astype(np.int32)

    # the history rings silently alias once a read offset wraps: a
    # "delayed" read would return recent/future data. Guard both readers
    # (on the pre-cast floats — an int32-wrapped offset must not pass).
    max_rtt = int(np.max(2 * np.asarray(table.path_prop_us) // cfg.dt_us,
                         initial=1))
    max_sig = int(sig_delay_f.max(initial=0))
    if max(max_rtt, max_sig) >= HIST:
        raise ValueError(
            f"history ring too short: HIST={HIST} steps but the worst path "
            f"needs rtt={max_rtt} and signal-delay={max_sig} steps at "
            f"dt_us={cfg.dt_us} (sig_delay_scale={cfg.sig_delay_scale}); "
            "increase dt_us or reduce sig_delay_scale")

    # arrivals bucketed by step — vectorized (at 200k flows the per-flow
    # Python loop this replaces was a real build cost). Stable argsort
    # keeps flows within a step in ascending-index order, exactly the
    # order the old loop filled slots in (bit-identical, see tests).
    T = cfg.num_steps
    step = np.minimum(flows.arrival_us // cfg.dt_us, T - 1).astype(np.int64)
    counts = np.bincount(step, minlength=T)
    A = max(int(counts.max()), 1)
    arrivals = np.full((T, A), -1, np.int32)
    order = np.argsort(step, kind="stable")
    srt = step[order]
    # slot within the step = rank among same-step flows (cumcount):
    # searchsorted on the sorted array gives each element's first index
    slot = np.arange(len(srt)) - np.searchsorted(srt, srt, side="left")
    arrivals[srt, slot] = order

    # failure / degradation schedules -> per-link step arrays (the legacy
    # single-event fields fold into the same representation)
    fail_step = np.full(L, _NEVER, np.int32)
    if cfg.fail_link >= 0:
        fail_step[cfg.fail_link] = cfg.fail_at_us // cfg.dt_us
    for li, at_us in cfg.fail_sched:
        fail_step[li] = min(int(fail_step[li]), int(at_us) // cfg.dt_us)
    deg_step = np.full(L, _NEVER, np.int32)
    deg_factor = np.ones(L, np.float32)
    for li, at_us, fac in cfg.degrade_sched:
        deg_step[li] = int(at_us) // cfg.dt_us
        deg_factor[li] = float(fac)

    arr = SimArrays(
        link_cap=link_cap,
        link_cap_gbps=jnp.asarray(link_cap_gbps, jnp.int32),
        path_links=jnp.asarray(table.path_links),
        path_prop=jnp.asarray(table.path_prop_us),
        path_cap=jnp.asarray(table.path_cap * 125.0 * cfg.cap_scale, jnp.float32),
        path_cap_gbps=jnp.asarray(table.path_cap),
        path_first=jnp.asarray(table.path_first),
        pair_cand=jnp.asarray(table.pair_cand),
        arrivals=jnp.asarray(arrivals),
        f_arr_us=jnp.asarray(flows.arrival_us, jnp.float32),
        f_size=jnp.asarray(flows.size_bytes, jnp.float32),
        f_pair=jnp.asarray(flows.pair_id),
        f_id=jnp.asarray(flows.flow_id),
        policy_code=jnp.int32(policy_code(cfg.policy)
                              if cfg.policy != "sweep" else 0),
        link_fail_step=jnp.asarray(fail_step),
        link_deg_step=jnp.asarray(deg_step),
        link_deg_factor=jnp.asarray(deg_factor),
        path_len=jnp.asarray(table.path_len),
        link_delay_us=jnp.asarray(link_delay_us, jnp.int32),
        path_sig_delay=jnp.asarray(sig_delay),
        tables=tb,
    )
    F = flows.num_flows
    NPAIR, K = table.pair_cand.shape
    state = SimState(
        flow_path=jnp.full((F,), -1, jnp.int32),
        remaining=jnp.zeros((F,), jnp.float32),
        rate=jnp.zeros((F,), jnp.float32),
        active=jnp.zeros((F,), bool),
        done=jnp.zeros((F,), bool),
        fct_us=jnp.zeros((F,), jnp.float32),
        extra_wait=jnp.zeros((F,), jnp.float32),
        rtt_steps=jnp.ones((F,), jnp.int32),
        route_step=jnp.full((F,), 1 << 20, jnp.int32),   # sentinel: unrouted
        route_nonce=jnp.zeros((F,), jnp.int32),
        last_dec=jnp.full((F,), -(1 << 20), jnp.int32),
        cc_alpha=jnp.zeros((F,), jnp.float32),
        cc_target=jnp.zeros((F,), jnp.float32),
        prev_delay=jnp.zeros((F,), jnp.float32),
        q_bytes=jnp.zeros((L,), jnp.float32),
        hist_q=jnp.zeros((L, HIST), jnp.float32),
        hist_u=jnp.zeros((L, HIST), jnp.float32),
        hist_c=jnp.zeros((L, HIST), jnp.int32),
        u_ewma=jnp.zeros((L,), jnp.float32),
        link_alive=jnp.ones((L,), bool),
        serv_bytes=jnp.zeros((L,), jnp.float32),
        cong=CongState.init(L),
        c_cong=jnp.zeros((L,), jnp.int32),
        c_path=c_path,
        redte_w=jnp.ones((NPAIR, K), jnp.int32),
    )
    return arr, state


def _infer_link_caps(table: PathTable) -> np.ndarray:
    """Recover per-link capacities from path hop data (bottleneck-safe:
    every link appears in some path with its true cap recorded at build
    time via topo arrays — we stash them on the table)."""
    if hasattr(table, "_link_caps"):
        return table._link_caps  # set by attach_link_caps
    raise ValueError("call attach_link_caps(table, topo) before build()")


def _infer_link_delays(table: PathTable) -> np.ndarray:
    if hasattr(table, "_link_delays"):
        return table._link_delays  # set by attach_link_caps
    raise ValueError("call attach_link_caps(table, topo) before build()")


def attach_link_caps(table: PathTable, topo) -> PathTable:
    _, _, cap, dly = topo.arrays()
    object.__setattr__(table, "_link_caps", cap.astype(np.float32))
    object.__setattr__(table, "_link_delays", dly.astype(np.int64))
    return table


# ---------------------------------------------------------- shared step parts
def path_cong_view(hist_c: jnp.ndarray, path_links: jnp.ndarray,
                   sig_delay: jnp.ndarray, t) -> jnp.ndarray:
    """Ingress-visible congestion of candidate paths at step ``t``.

    The max over hops of each hop's *quantized* ``C_cong`` (the
    ``core.cong`` register-pipeline output recorded in the ``hist_c``
    ring), read ``sig_delay`` steps late — the one-way propagation
    distance the signal travels back to the ingress. A remote hop's
    congestion can never be seen earlier than physics delivers it.

    ``path_links``/``sig_delay``: (..., H) hop link indices (-1 pad) and
    per-hop delay offsets; returns (...,) int32 scores.
    """
    lidx = jnp.maximum(path_links, 0)
    slot = jnp.asarray((t - sig_delay) % HIST, jnp.int32)
    v = hist_c.reshape(-1)[lidx * HIST + slot]
    return jnp.where(path_links >= 0, v, 0).max(-1)


def ctrl_refresh(t, st: SimState, ar: SimArrays, cfg: SimConfig) -> jnp.ndarray:
    """One control-plane tick (paper §3.2 install, §7.3 update period):
    recompute the C_path table from *effective* per-link capacities — the
    degrade schedule and link liveness applied — via the shared
    ``core.pathq`` helpers. Propagation delays are physical and static;
    only the capacity term can change at runtime."""
    eff = ar.link_cap_gbps * jnp.where(t >= ar.link_deg_step,
                                       ar.link_deg_factor, 1.0)
    eff = jnp.where(st.link_alive, eff, 0.0).astype(jnp.int32)
    _, cap_eff = path_bottleneck_stats(ar.link_delay_us, eff,
                                       ar.path_links, ar.path_len)
    return calc_path_quality(ar.path_prop, cap_eff,
                             ar.tables.cap_thresh, cfg.pathq)


def monitor_tick(t, st, ar: SimArrays, cfg: SimConfig):
    """Switch monitor pass (every dt — the paper's "modest cadence"):
    run the ``core.cong`` register pipeline on current queue depths and
    land the quantized score in the ``hist_c`` ring at slot ``t``, where
    ingress decisions read it back hop-by-hop with propagation delay.
    Identical for both engines — only the queue dynamics feeding
    ``st.q_bytes`` differ."""
    qcells = (st.q_bytes / CELL_BYTES).astype(jnp.int32)
    cong = congmod.monitor_update(st.cong, qcells, t * cfg.dt_us,
                                  ar.tables, cfg.congp)
    c_cong = congmod.calc_cong_cost(cong, ar.tables, cfg.congp)
    return dataclasses.replace(
        st, cong=cong, c_cong=c_cong,
        hist_c=st.hist_c.at[:, jnp.asarray(t % HIST, jnp.int32)].set(
            c_cong, mode=RING_SCATTER_MODE))


def ctrl_tick(t, st, ar: SimArrays, cfg: SimConfig):
    """Periodic control-plane C_path re-install (``ctrl_refresh`` every
    ``ctrl_period_us``). Skipped entirely when no schedule can change the
    effective capacities (the refresh would be a no-op) or when the
    period is 0 (frozen build-time table)."""
    if cfg.ctrl_period_us > 0 and (cfg.has_failures or cfg.has_degrade):
        period = max(cfg.ctrl_period_us // cfg.dt_us, 1)
        st = dataclasses.replace(
            st, c_path=jnp.where((t % period) == 0,
                                 ctrl_refresh(t, st, ar, cfg), st.c_path))
    return st


def redte_tick(t, st, ar: SimArrays, cfg: SimConfig):
    """RedTE periodic split-ratio re-optimization (100 ms loop). In sweep
    mode the weights are maintained unconditionally (cheap (NPAIR,K)
    integer ops) — only redte-coded cells ever read them."""
    if cfg.policy == "redte" or (cfg.policy == "sweep"
                                 and "redte" in cfg.sweep_policies):
        period = max(cfg.redte_period_us // cfg.dt_us, 1)
        due = (t % period) == 0
        util_q8 = jnp.clip(st.u_ewma * 256, 0, 255).astype(jnp.int32)
        first = ar.path_first[jnp.maximum(ar.pair_cand, 0)]
        head = jnp.maximum(256 - util_q8[first], 1)
        w = jnp.where(ar.pair_cand >= 0, head, 0).astype(jnp.int32)
        st = dataclasses.replace(
            st, redte_w=jnp.where(due, w, st.redte_w))
    return st


def _path_queue_wait(st: SimState, ar: SimArrays, path_idx) -> jnp.ndarray:
    """Standing-queue wait a path's first packets see: sum over hops of
    queue bytes / link capacity. ``path_idx`` must be pre-clamped >= 0."""
    hop = ar.path_links[path_idx]
    return jnp.where(hop >= 0, st.q_bytes[jnp.maximum(hop, 0)]
                     / ar.link_cap[jnp.maximum(hop, 0)], 0.0).sum(-1)


def decide(t, fid, pair, st: SimState, ar: SimArrays, cfg: SimConfig,
           sig_step=None):
    """The single policy-dispatched path-decision core.

    Every caller that turns (hash key, pair) into a candidate choice —
    arrival routing, lazy failover, and the mid-flow re-decision tick —
    goes through here, so all policies apply *their own law* at every
    decision point and sweep-mode dynamic dispatch is implemented once.

    ``fid``: (N,) u32 hash keys. Re-decision callers salt these with the
    flow's nonce so a re-hash can land elsewhere; nonce 0 leaves the key
    unchanged (``fmix32(0) == 0``), preserving arrival decisions exactly.
    ``sig_step``: the step whose ``hist_c`` slot the congestion view
    reads (default ``t``; the failover caller runs before this step's
    monitor tick and passes ``t - 1``).

    Returns ``(k_idx, chosen)``: (N,) candidate-slot index and (N,)
    global path index, both -1 where no valid candidate exists.
    """
    cand = ar.pair_cand[pair]                                   # (N, K)
    cpad = jnp.maximum(cand, 0)

    # candidate liveness: every hop of the path must be alive
    hop = ar.path_links[cpad]                                   # (N,K,H)
    hop_alive = jnp.where(hop >= 0, st.link_alive[jnp.maximum(hop, 0)], True)
    valid = (cand >= 0) & hop_alive.all(-1)

    c_path = st.c_path[cpad]
    c_cong = path_cong_view(st.hist_c, hop, ar.path_sig_delay[cpad],
                            t if sig_step is None else sig_step)
    delay = ar.path_prop[cpad]
    capg = ar.path_cap_gbps[cpad]

    def _choice(policy: str) -> jnp.ndarray:
        if policy in ("lcmp", "lcmp_r"):    # lcmp_r differs only in the
            return selmod.select_egress(fid, c_path, c_cong, valid,  # tick
                                        cfg.select)[0]
        if policy == "lcmp_w":  # beyond-paper: capacity-weighted stage 2
            return selmod.select_egress(fid, c_path, c_cong, valid,
                                        cfg.select, weights=capg)[0]
        if policy in ("ecmp", "amp"):       # amp = per-subflow ECMP hash
            return bl.ecmp(fid, delay, capg, valid)
        if policy == "ucmp":
            return bl.ucmp(fid, delay, capg, valid)
        if policy == "wcmp":
            return bl.wcmp(fid, delay, capg, valid)
        if policy == "redte":
            return bl._weighted_hash(fid, st.redte_w[pair], valid)
        if policy == "fatpaths":
            return bl.fatpaths(fid, ar.path_len[cpad], valid, c_cong,
                               cong_thresh=cfg.select.cong_fallback)
        if policy == "matchrdma":
            # matched rate per candidate: the tightest span's *effective*
            # capacity (degrade schedule applied at decision time — the
            # per-span rate matching) x the congestion headroom seen at
            # the ingress. The headroom reads the SAME delayed signal
            # plane LCMP does (c_cong via hist_c + path_sig_delay) — a
            # rate-matching loop learns about congestion one telemetry
            # RTT late too, no oracle. Padding hops never bind the min.
            eff = ar.link_cap_gbps * jnp.where(
                t >= ar.link_deg_step, ar.link_deg_factor, 1.0)
            lidx = jnp.maximum(hop, 0)
            bneck = jnp.where(hop >= 0, eff[lidx],
                              jnp.float32(1e9)).min(-1)          # (N, K)
            avail = bneck * (256 - c_cong).astype(jnp.float32)
            return bl.matchrdma(
                fid, jnp.minimum(avail, 1e9).astype(jnp.int32), valid)
        raise ValueError(policy)

    if cfg.policy == "sweep":
        # dynamic dispatch on the per-experiment code: every *swept*
        # policy's decision is computed (m<=8 candidates — cheap relative
        # to the per-flow state updates) and the cell's one is gathered,
        # so a vmapped batch can mix policies inside a single trace.
        codes = jnp.asarray([policy_code(p) for p in cfg.sweep_policies],
                            jnp.int32)
        k_all = jnp.stack([_choice(p) for p in cfg.sweep_policies])
        k_idx = jnp.take(k_all, jnp.argmax(codes == ar.policy_code), axis=0)
    else:
        k_idx = _choice(cfg.policy)

    chosen = jnp.take_along_axis(cand, jnp.maximum(k_idx, 0)[:, None],
                                 axis=1)[:, 0]
    chosen = jnp.where(k_idx >= 0, chosen, -1)                  # (N,)
    return k_idx, chosen


def _route_arrivals(t, st: SimState, ar: SimArrays, cfg: SimConfig):
    """Decide paths for the batch of flows arriving this step."""
    idx = ar.arrivals[t]                        # (A,)
    is_flow = idx >= 0
    fidx = jnp.maximum(idx, 0)
    pair = ar.f_pair[fidx]                      # (A,)

    _, chosen = decide(t, ar.f_id[fidx], pair, st, ar, cfg)
    chosen = jnp.where(is_flow, chosen, -1)                     # (A,)

    ok = chosen >= 0
    cpath_sel = jnp.maximum(chosen, 0)
    # queue wait seen by the first packets (standing queues on the path)
    qw = _path_queue_wait(st, ar, cpath_sel)

    rtt = jnp.maximum(2 * ar.path_prop[cpath_sel] // cfg.dt_us, 1)

    F = st.flow_path.shape[0]

    def upd(a, vals, where_ok):
        # pad slots / no-decision flows scatter out of bounds and drop:
        # writing a[fidx=0] for pads would race a real flow-0 arrival in
        # the same batch and make results depend on the pad width (which
        # the sweep engine varies when stacking cells).
        return a.at[jnp.where(where_ok, fidx, F)].set(vals, mode="drop")

    st = dataclasses.replace(
        st,
        flow_path=upd(st.flow_path, chosen, ok),
        remaining=upd(st.remaining, ar.f_size[fidx], ok),
        rate=upd(st.rate, ar.path_cap[cpath_sel], ok),
        cc_target=upd(st.cc_target, ar.path_cap[cpath_sel], ok),
        active=upd(st.active, ok, ok),
        extra_wait=upd(st.extra_wait, qw, ok),
        rtt_steps=upd(st.rtt_steps, rtt.astype(jnp.int32), ok),
        route_step=upd(st.route_step,
                       jnp.full(fidx.shape, 0, jnp.int32) + t, ok),
    )
    return st


def _cc_update(t, st: SimState, ar: SimArrays, cfg: SimConfig,
               path_of_flow, links_f, links_ok):
    """Rate laws reacting to RTT-delayed per-path congestion signals.

    Realism notes (these interact with the routing signal, see DESIGN):
    - ECN marking is RED-style probabilistic between Kmin and Kmax, so the
      equilibrium queue *grows with the number of backlogged flows* — a
      CC that pinned queues at Kmin regardless of load would blind the
      switch's Q estimator (and real DCQCN does not).
    - DCQCN-style decrease/recovery: MD cuts both rate and target; the
      increase phase fast-recovers halfway to target per RTT and only
      probes (+AI on target) once recovered. Without a target bound, N
      backlogged flows each AI-ing a line-rate fraction diverge.

    Both engines call this verbatim: the fluid engine applies ``rate``
    directly as the sending rate; the packet engine paces injection with
    it and bounds in-flight bytes by the rate-BDP window — the "per-flow
    windows driven by the same CC laws" contract.
    """
    slot = jnp.asarray((t - st.rtt_steps) % HIST, jnp.int32)
    # Feedback exists only once the flow's own first packets have had a
    # full RTT on its *current* path: gate on steps since the flow's
    # routing step, not the global clock — otherwise a flow arriving at
    # t >> RTT immediately reads congestion history recorded *before* it
    # was routed (stale signals from traffic it never shared a path with).
    have_fb = (t - st.route_step) > st.rtt_steps
    lidx = jnp.maximum(links_f, 0)                              # (F,H)
    flat = lidx * HIST + slot[:, None]
    q_sig = jnp.where(links_ok, st.hist_q.reshape(-1)[flat], 0.0).max(-1)
    u_sig = jnp.where(links_ok, st.hist_u.reshape(-1)[flat], 0.0).max(-1)
    q_sig = jnp.where(have_fb, q_sig, 0.0)
    u_sig = jnp.where(have_fb, u_sig, 0.0)

    line = ar.path_cap[jnp.maximum(path_of_flow, 0)]
    # the CC control loop operates per RTT; discretize increments per step
    inv_rtt = 1.0 / st.rtt_steps.astype(jnp.float32)
    ai = cfg.ai_frac * line * inv_rtt          # ai_frac = per-RTT probe frac
    # MD cadence: a reaction timer, never slower than one per RTT and
    # never faster than ~8 decreases per feedback epoch (the rtt//8
    # floor bounds how often a flow can cut on the *same* stale signal)
    dec_gap = jnp.minimum(
        st.rtt_steps,
        jnp.maximum(max(cfg.cc_dec_period_us // cfg.dt_us, 1),
                    st.rtt_steps // 8))
    can_dec = (t - st.last_dec) >= dec_gap

    # RED-style marking probability from the delayed queue signal
    kmin = cfg.ecn_kmin_bytes * cfg.cap_scale
    kmax = cfg.ecn_kmax_factor * kmin
    p_mark = jnp.clip((q_sig - kmin) / (kmax - kmin), 0.0, 1.0)
    u01 = (selmod.fmix32(ar.f_id ^ jnp.uint32(t)).astype(jnp.float32)
           * (1.0 / 4294967296.0))
    marked = u01 < p_mark

    target = jnp.maximum(st.cc_target, 0.05 * line)

    def aimd(dec_event, md_rate):
        """Shared DCQCN-shaped decrease/fast-recovery/probe machinery.
        Recovery moves halfway to target per *RTT* (not per step) and the
        target probes +ai_frac of line per RTT once recovered."""
        dec = dec_event & can_dec
        new_target = jnp.where(dec, st.rate, target)
        recover = st.rate + (new_target - st.rate) * 0.5 * inv_rtt
        probe = jnp.where(st.rate >= 0.95 * new_target, ai, 0.0)
        rate = jnp.where(dec, st.rate * md_rate, recover + probe)
        new_target = jnp.where(dec, new_target, new_target + probe)
        return rate, new_target, dec

    if cfg.cc == "dcqcn":
        rate, new_target, dec = aimd(marked, cfg.md_factor)
        alpha, pdel = st.cc_alpha, st.prev_delay
    elif cfg.cc == "dctcp":
        alpha = st.cc_alpha * (1 - 1 / 16) + marked.astype(jnp.float32) / 16
        rate, new_target, dec = aimd(marked, 1.0 - alpha / 2)
        pdel = st.prev_delay
    elif cfg.cc == "timely":
        lcap = ar.link_cap[lidx]
        d_us = jnp.where(links_ok, st.hist_q.reshape(-1)[flat] / lcap, 0.0).max(-1)
        d_us = jnp.where(have_fb, d_us, 0.0)
        grad = d_us - st.prev_delay
        t_high = 2.0 * kmin / line
        rate, new_target, dec = aimd(((d_us > t_high) | (grad > 0)) & (d_us > 0),
                                     cfg.md_factor)
        alpha, pdel = st.cc_alpha, d_us
    elif cfg.cc == "hpcc":
        eta = 0.95
        bdp = line * jnp.maximum(st.rtt_steps.astype(jnp.float32) * cfg.dt_us, 1.0)
        u_tot = u_sig + q_sig / jnp.maximum(bdp, 1.0)   # inflight-based U
        corr = jnp.clip(eta / jnp.maximum(u_tot, 1e-3), 0.3, 1.0)
        rate, new_target, dec = aimd(u_tot > eta, 1.0)  # md via corr below
        rate = jnp.where(dec, st.rate * corr, rate)
        alpha, pdel = st.cc_alpha, st.prev_delay
    else:
        raise ValueError(cfg.cc)

    rate = jnp.clip(rate, 0.001 * line, line)
    new_target = jnp.clip(new_target, 0.001 * line, line)
    last_dec = jnp.where(dec, jnp.int32(t), st.last_dec)
    act = st.active
    return dataclasses.replace(
        st, rate=jnp.where(act, rate, st.rate),
        cc_target=jnp.where(act, new_target, st.cc_target),
        cc_alpha=alpha, prev_delay=pdel,
        last_dec=jnp.where(act, last_dec, st.last_dec))


def _reroute_dead(t, st: SimState, ar: SimArrays, cfg: SimConfig) -> SimState:
    """Re-decide every active flow whose pinned path lost a link (the
    data-plane lazy-failover semantics, vectorized over all flows once at
    the trip step). Failover goes through the shared decision core, so
    every policy — wcmp/ucmp/redte cells in a sweep included — re-decides
    under its *own* law against the post-trip liveness mask.

    The reroute runs before this step's monitor tick, so slot t is not
    yet written: the freshest signal physics offers here is step t-1."""
    hop = ar.path_links[jnp.maximum(st.flow_path, 0)]
    dead = jnp.where(hop >= 0, ~st.link_alive[jnp.maximum(hop, 0)], False).any(-1)
    move = st.active & dead & (st.flow_path >= 0)

    k_idx, new_path = decide(t, ar.f_id, ar.f_pair, st, ar, cfg,
                             sig_step=t - 1)
    ok = move & (k_idx >= 0)
    npad = jnp.maximum(new_path, 0)
    # CC state re-initializes with the path: a rerouted flow is "first
    # packets" again — target line rate of the NEW path, a fresh MD
    # timer, and the new path's standing-queue wait (not the dead one's)
    qw = _path_queue_wait(st, ar, npad)
    return dataclasses.replace(
        st,
        flow_path=jnp.where(ok, new_path, st.flow_path),
        rate=jnp.where(ok, ar.path_cap[npad], st.rate),
        cc_target=jnp.where(ok, ar.path_cap[npad], st.cc_target),
        last_dec=jnp.where(ok, jnp.int32(-(1 << 20)), st.last_dec),
        cc_alpha=jnp.where(ok, 0.0, st.cc_alpha),
        prev_delay=jnp.where(ok, 0.0, st.prev_delay),
        extra_wait=jnp.where(ok, qw, st.extra_wait),
        rtt_steps=jnp.where(
            ok, jnp.maximum(2 * ar.path_prop[npad]
                            // cfg.dt_us, 1).astype(jnp.int32), st.rtt_steps),
        route_step=jnp.where(ok, jnp.int32(0) + t, st.route_step),
        active=jnp.where(move & (k_idx < 0), False, st.active))


def wants_redecide(cfg: SimConfig) -> bool:
    """Python-level (trace-time) gate for the mid-flow re-decision plane:
    true iff the engine's eligibility knob is armed AND some policy in
    the dispatch set actually re-decides. False keeps the step function
    bit-identical to the pinned-path program (no extra ops traced)."""
    knob = (cfg.flowlet_gap_us if cfg.engine == "packet"
            else cfg.redecide_period_us)
    if knob <= 0:
        return False
    pols = cfg.sweep_policies if cfg.policy == "sweep" else (cfg.policy,)
    return any(p in REDECIDE_POLICIES for p in pols)


def redecide_tick(t, st: SimState, ar: SimArrays, cfg: SimConfig,
                  eligible) -> SimState:
    """Mid-flow re-decision for eligible active flows (the third caller
    of ``decide``). ``eligible`` is the engine-specific trigger mask:
    the packet engine passes flowlet idle-gap detection, the fluid
    engine an all-true mask under a ``redecide_period_us`` timer cond.

    Each opportunity bumps the flow's nonce, and the decision hashes on
    ``f_id ^ fmix32(nonce)`` — a fresh pseudo-random key per flowlet
    (FatPaths re-hash semantics) that still replays deterministically.
    Unlike failover, a path change here keeps the flow's CC rate state:
    a flowlet switch is the same transport entity continuing on a new
    path, not a restart — only the route bookkeeping (RTT, route step,
    standing-queue wait) follows the new path. The feedback gate in
    ``_cc_update`` then holds rates steady until the new path's own
    signals are a full RTT old."""
    move = st.active & (st.flow_path >= 0) & eligible & (t > st.route_step)
    if cfg.policy == "sweep":
        # only re-decision-capable cells may move; others stay pinned
        # bit-for-bit even when sharing the trace with fatpaths/lcmp_r
        cell_ok = jnp.asarray(False)
        for p in cfg.sweep_policies:
            if p in REDECIDE_POLICIES:
                cell_ok = cell_ok | (ar.policy_code == policy_code(p))
        move = move & cell_ok
    elif cfg.policy not in REDECIDE_POLICIES:
        return st

    nonce = st.route_nonce + move.astype(jnp.int32)
    fid = ar.f_id ^ selmod.fmix32(nonce.astype(jnp.uint32))
    k_idx, new_path = decide(t, fid, ar.f_pair, st, ar, cfg)
    changed = move & (k_idx >= 0) & (new_path != st.flow_path)
    npad = jnp.maximum(new_path, 0)
    qw = _path_queue_wait(st, ar, npad)
    rtt = jnp.maximum(2 * ar.path_prop[npad] // cfg.dt_us, 1).astype(jnp.int32)
    return dataclasses.replace(
        st,
        route_nonce=nonce,
        flow_path=jnp.where(changed, new_path, st.flow_path),
        rtt_steps=jnp.where(changed, rtt, st.rtt_steps),
        route_step=jnp.where(changed, jnp.int32(0) + t, st.route_step),
        extra_wait=jnp.where(changed, qw, st.extra_wait))
