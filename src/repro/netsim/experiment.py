"""One-call experiment driver: topology + workload + policy -> FCT stats.

This is the unit the benchmark harness (one per paper figure) composes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.netsim import fluid, metrics, paths, topo
from repro.netsim.fluid import SimConfig
from repro.traffic import cdf as cdfmod
from repro.traffic.gen import generate


@dataclasses.dataclass(frozen=True)
class ExpSpec:
    topology: str = "testbed8"       # testbed8 | bso13 | parallel
    workload: str = "websearch"
    load: float = 0.3
    policy: str = "lcmp"
    cc: str = "dcqcn"
    duration_us: int = 1_500_000
    seed: int = 0
    pairs: str = "dc1dc8"            # dc1dc8 | all | <src>-<dst>
    cap_scale: float = 0.125
    select: Optional[object] = None  # optional SelectParams override
    pathq: Optional[object] = None   # optional PathQParams override
    congp: Optional[object] = None   # optional CongParams override


_TOPOS = {
    "testbed8": topo.testbed_8dc,
    "bso13": topo.bso_13dc,
}


def build_experiment(spec: ExpSpec):
    t = _TOPOS[spec.topology]()
    pair_list = paths.all_pairs(t)
    table = paths.build_path_table(t, pair_list)
    fluid.attach_link_caps(table, t)
    pidx = table.pair_index()

    if spec.pairs == "dc1dc8":
        traffic_pairs = [pidx[(0, 7)]]
    elif spec.pairs == "all":
        traffic_pairs = [pidx[p] for p in pair_list
                         if table.pair_ncand[pidx[p]] > 0]
    else:
        s, d = spec.pairs.split("-")
        traffic_pairs = [pidx[(int(s), int(d))]]

    flows = generate(table, cdfmod.WORKLOADS[spec.workload], spec.load,
                     spec.duration_us, pair_ids=traffic_pairs, seed=spec.seed,
                     cap_scale=spec.cap_scale)

    kw = {}
    if spec.select is not None:
        kw["select"] = spec.select
    if spec.pathq is not None:
        kw["pathq"] = spec.pathq
    if spec.congp is not None:
        kw["congp"] = spec.congp
    cfg = SimConfig(policy=spec.policy, cc=spec.cc,
                    horizon_us=spec.duration_us * 2,   # let tail flows finish
                    cap_scale=spec.cap_scale, **kw)
    return t, table, flows, cfg


def run_experiment(spec: ExpSpec):
    t, table, flows, cfg = build_experiment(spec)
    arrs, state = fluid.build(table, flows, cfg)
    final = fluid.run(arrs, state, cfg)
    stats = metrics.fct_stats(final, table, flows, cfg)
    util = metrics.link_utilization(final, arrs, cfg)
    return stats, util, (t, table, flows, cfg, final)


def compare_policies(base: ExpSpec, policies: Sequence[str]) -> Dict[str, metrics.FCTStats]:
    out = {}
    for p in policies:
        stats, _, _ = run_experiment(dataclasses.replace(base, policy=p))
        out[p] = stats
    return out
