"""One-call experiment driver: scenario + workload + policy -> FCT stats.

This is the unit the benchmark harness (one per paper figure) composes.
``ExpSpec.topology`` accepts any registered scenario string (see
``repro.netsim.scenarios``), including parameterized ones like
``"longhaul_mesh:routes=8,segs=3"``. ``ExpSpec.engine`` selects the
simulation backend (``"fluid"`` or ``"packet"``, see
``repro.netsim.engine``) — every scenario/axis runs on either. The
helpers are factored so the batched sweep engine (``repro.netsim.sweep``)
can share the cached world-building and flow-generation steps while
replacing the one-cell ``run`` with a single vmapped invocation.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Dict, Optional, Sequence

from repro.netsim import engine as enginemod
from repro.netsim import fluid, metrics, paths, scenarios
from repro.netsim.engine import SimConfig
from repro.traffic import cdf as cdfmod
from repro.traffic import sched as schedmod
from repro.traffic.gen import generate


@dataclasses.dataclass(frozen=True)
class ExpSpec:
    topology: str = "testbed8"       # any scenario string (scenarios.names())
    workload: str = "websearch"
    load: float = 0.3
    policy: str = "lcmp"
    cc: str = "dcqcn"
    engine: str = "fluid"            # fluid | packet (engine.ENGINES)
    duration_us: int = 1_500_000
    seed: int = 0
    pairs: str = "main"              # main | all | <src>-<dst>
    # background cross-traffic: every advertised pair NOT in ``pairs`` is
    # dosed at this load while the foreground pairs run at ``load`` (0 =
    # no cross-traffic). A dynamic sweep axis like load/seed/pairs — it
    # only changes flow-table contents, never the compiled program.
    bg_load: float = 0.0
    # per-pair piecewise load schedule (traffic/sched.py wire string,
    # e.g. "diurnal:amp=0.8,segs=24"; "" = static scalar load). Another
    # dynamic sweep axis: schedules reshape the flow tables only, so
    # cells with different schedules share one compiled trace.
    load_sched: str = ""
    cap_scale: float = 0.125
    # signal-plane staleness axes (§7.3 ablations; both static/trace-level)
    sig_delay_scale: float = 1.0     # routing-signal propagation-delay scale
    ctrl_period_us: int = 100_000    # C_path re-install period (0 = frozen)
    # mid-flow re-decision plane (static/trace-level axes; 0/0/1 = off,
    # bit-identical to pinned-path routing — see engine.wants_redecide):
    flowlet_gap_us: int = 0          # packet engine: flowlet idle gap
    redecide_period_us: int = 0      # fluid engine: re-decision epoch
    n_subflows: int = 1              # amp: subflows per flow (gen + metrics)
    # training co-simulation overlay (repro.cosim): a configs/ arch alias
    # ("" = off — the flow tables, and therefore every result, stay
    # bit-for-bit the legacy output). All four are dynamic axes: they
    # only append deterministic collective rows to the flow tables,
    # never touch the compiled program.
    cosim_model: str = ""            # e.g. "qwen3-4b"; "" disables cosim
    cosim_cell: str = "train_4k"     # launch/shapes.py train cell
    cosim_iters: int = 6             # training iterations over duration_us
    cosim_compress: int = 1          # int8+scales wire (dist.compress)
    # debug mode: thread the checkify physics-invariant sanitizer through
    # the scan (repro.netsim.sanitize). Static axis — the checked program
    # is a different trace; REPRO_CHECKS=1 in the environment forces it
    # on for any spec (the CI sanitize smoke uses this).
    checks: int = 0
    select: Optional[object] = None  # optional SelectParams override
    pathq: Optional[object] = None   # optional PathQParams override
    congp: Optional[object] = None   # optional CongParams override


# Sweep-axis contract, machine-checked by `python -m repro.analysis`
# (reprolint AXS001-AXS003): every ExpSpec field is either *static* — it
# reaches the compiled trace through spec_to_cfg, so sweep cells that
# differ in it cannot share a compiled program — or *dynamic* — it only
# reshapes the padded per-cell flow tables, so cells that differ in it
# MUST share one program. A new field that lands in neither table fails
# lint until it is classified (or exempted with a justification).
AXES_STATIC = (
    "engine", "cc", "duration_us", "cap_scale", "sig_delay_scale",
    "ctrl_period_us", "flowlet_gap_us", "redecide_period_us",
    "n_subflows", "checks", "select", "pathq", "congp",
)
AXES_DYNAMIC = (
    "workload", "load", "seed", "pairs", "bg_load", "load_sched",
    "cosim_model", "cosim_cell", "cosim_iters", "cosim_compress",
)
AXES_EXEMPT = {
    "topology": "enters the trace key via sweep.static_key (world shapes),"
                " not via spec_to_cfg — cells never mix topologies",
    "policy": "dynamic per-cell policy_code at runtime; the spec_to_cfg"
              " read is overridden by static_key's policy='sweep' replace",
}


@functools.lru_cache(maxsize=32)
def build_world(topology: str):
    """Scenario + path table for a scenario string (cached: sweeps hit the
    same world for every cell of a figure grid, and the DFS path
    enumeration on the 13-DC mesh is the expensive numpy part)."""
    scen = scenarios.get(topology)
    t = scen.topology
    # scenarios with helper nodes (wan2000's OTN segment nodes) advertise
    # their real DC endpoints and enumeration budget; the default is every
    # node pair under the stock install policy (bit-identical to before)
    pair_list = (list(scen.traffic_pairs) if scen.traffic_pairs is not None
                 else paths.all_pairs(t))
    table = paths.build_path_table(t, pair_list, max_hops=scen.max_hops,
                                   detour_delay=scen.detour_delay,
                                   detour_hops=scen.detour_hops)
    fluid.attach_link_caps(table, t)
    return scen, table


def traffic_pair_ids(spec: ExpSpec, scen: scenarios.Scenario, table) -> list:
    pidx = table.pair_index()
    if spec.pairs in ("main", "dc1dc8"):     # dc1dc8: legacy spelling
        main = pidx[scen.main_pair]
        if table.pair_ncand[main] == 0:
            raise ValueError(
                f"scenario {spec.topology!r}: main pair {scen.main_pair} has "
                "no installed candidate paths (parameters out of range?)")
        return [main]
    if spec.pairs == "all":
        return [pidx[p] for p in pidx if table.pair_ncand[pidx[p]] > 0]
    s, d = spec.pairs.split("-")
    return [pidx[(int(s), int(d))]]


def background_pair_ids(table, fg_ids) -> list:
    """Cross-traffic pairs: every advertised pair with candidates that is
    not a foreground pair."""
    fg = set(int(i) for i in fg_ids)
    return [i for i in range(len(table.pair_src))
            if table.pair_ncand[i] > 0 and i not in fg]


def make_flows(spec: ExpSpec, scen: scenarios.Scenario, table):
    fg_ids = traffic_pair_ids(spec, scen, table)
    bg_ids = (background_pair_ids(table, fg_ids)
              if spec.bg_load > 0 else None)
    kw = {}
    if spec.load_sched:
        sched_t, fg_rows, bg_rows = schedmod.build(
            spec.load_sched, spec.duration_us, table, scen,
            fg_ids, bg_ids or ())
        kw = dict(sched_t=sched_t, load_rows=fg_rows, bg_rows=bg_rows)
    fs = generate(table, cdfmod.WORKLOADS[spec.workload], spec.load,
                  spec.duration_us, pair_ids=fg_ids,
                  seed=spec.seed, cap_scale=spec.cap_scale,
                  bg_pair_ids=bg_ids, bg_load=spec.bg_load,
                  n_subflows=spec.n_subflows, **kw)
    if spec.cosim_model:
        # overlay the training job's collective bursts AFTER the full
        # legacy generation — the plan is rng-free and the merge is a
        # stable sort, so background rows stay bit-for-bit (pinned by
        # tests/test_cosim.py). Imported lazily: the cosim layer pulls
        # in the model-config registry, which plain netsim runs never
        # need.
        from repro.cosim import workload as cosim_workload
        plan = cosim_workload.build_plan(spec, scen, table)
        fs = cosim_workload.overlay(fs, plan)
    return fs


def spec_to_cfg(spec: ExpSpec, scen: scenarios.Scenario) -> SimConfig:
    kw = {}
    if spec.select is not None:
        kw["select"] = spec.select
    if spec.pathq is not None:
        kw["pathq"] = spec.pathq
    if spec.congp is not None:
        kw["congp"] = spec.congp
    return SimConfig(engine=spec.engine, policy=spec.policy, cc=spec.cc,
                     horizon_us=spec.duration_us * 2,  # let tail flows finish
                     cap_scale=spec.cap_scale,
                     sig_delay_scale=spec.sig_delay_scale,
                     ctrl_period_us=spec.ctrl_period_us,
                     flowlet_gap_us=spec.flowlet_gap_us,
                     redecide_period_us=spec.redecide_period_us,
                     n_subflows=spec.n_subflows,
                     checks=bool(spec.checks)
                     or os.environ.get("REPRO_CHECKS") == "1",
                     fail_sched=scen.fail_sched,
                     degrade_sched=scen.degrade_sched, **kw)


def build_experiment(spec: ExpSpec):
    scen, table = build_world(spec.topology)
    flows = make_flows(spec, scen, table)
    return scen.topology, table, flows, spec_to_cfg(spec, scen)


def run_experiment(spec: ExpSpec):
    t, table, flows, cfg = build_experiment(spec)
    eng = enginemod.get_engine(cfg.engine)
    arrs, state = eng.build(table, flows, cfg)
    final = eng.run(arrs, state, cfg)
    stats = metrics.fct_stats(final, table, flows, cfg)
    util = metrics.link_utilization(final, arrs, cfg)
    return stats, util, (t, table, flows, cfg, final)


def compare_policies(base: ExpSpec, policies: Sequence[str]) -> Dict[str, metrics.FCTStats]:
    out = {}
    for p in policies:
        stats, _, _ = run_experiment(dataclasses.replace(base, policy=p))
        out[p] = stats
    return out
