"""Candidate-path enumeration (control-plane side).

The paper's switches choose among *m candidate next-hops* toward each
destination (m in [2,8]). We enumerate, per (src,dst) pair, the best
simple path through each distinct first hop (bounded depth), which yields
exactly the per-next-hop candidate structure a DCI switch sees, and
precompute per-path attributes: hop link indices, propagation delay
(sum), bottleneck capacity (min).

Pure numpy — runs once at setup; the simulator consumes the packed arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.netsim.topo import Topology

MAX_HOPS = 5
MAX_CAND = 8    # paper: m in [2, 8]


@dataclasses.dataclass(frozen=True)
class PathTable:
    """Packed path/pair tables (all numpy, int32)."""
    # per path
    path_links: np.ndarray    # (NP, MAX_HOPS) link idx, -1 pad
    path_len: np.ndarray      # (NP,)
    path_prop_us: np.ndarray  # (NP,) sum of hop delays
    path_cap: np.ndarray      # (NP,) bottleneck Gbps
    path_first: np.ndarray    # (NP,) first-hop link idx
    # per (src,dst) pair with traffic
    pair_src: np.ndarray      # (NPAIR,)
    pair_dst: np.ndarray      # (NPAIR,)
    pair_cand: np.ndarray     # (NPAIR, MAX_CAND) path idx, -1 pad
    pair_ncand: np.ndarray    # (NPAIR,)
    pair_ideal_prop: np.ndarray  # (NPAIR,) us — min-prop candidate
    pair_ideal_cap: np.ndarray   # (NPAIR,) Gbps — bottleneck cap of that path

    @property
    def num_paths(self) -> int:
        return len(self.path_len)

    def pair_index(self) -> Dict[Tuple[int, int], int]:
        return {(int(s), int(d)): i
                for i, (s, d) in enumerate(zip(self.pair_src, self.pair_dst))}


def _enumerate_simple_paths(adj, src, dst, max_hops):
    """DFS all simple paths src->dst up to max_hops links."""
    out: List[List[int]] = []
    stack = [(src, [], {src})]
    while stack:
        node, links_so_far, visited = stack.pop()
        if len(links_so_far) >= max_hops:
            continue
        for (nbr, li) in adj[node]:
            if nbr == dst:
                out.append(links_so_far + [li])
            elif nbr not in visited:
                stack.append((nbr, links_so_far + [li], visited | {nbr}))
    return out


def build_path_table(topo: Topology, pairs: List[Tuple[int, int]],
                     max_hops: int = MAX_HOPS, max_cand: int = MAX_CAND,
                     detour_delay: float = 1.5, detour_hops: int = 1) -> PathTable:
    """``detour_*`` implement the control-plane installation policy: a
    candidate is only installed if its propagation delay is within
    ``detour_delay`` x the pair's best and its hop count within
    ``detour_hops`` of the shortest — nobody routes a 200 km pair the long
    way around Europe. (Without this every ring pair is 'multi-path' and
    the paper's 25.6% multi-path statistic on the 13-DC topology is
    unreproducible.)"""
    src_a, dst_a, cap_a, del_a = topo.arrays()
    adj: Dict[int, List[Tuple[int, int]]] = {n: [] for n in range(topo.num_nodes)}
    for li, (s, d) in enumerate(zip(src_a, dst_a)):
        adj[int(s)].append((int(d), li))

    all_paths: List[List[int]] = []
    pair_rows = []
    for (s, d) in pairs:
        cands = _enumerate_simple_paths(adj, s, d, max_hops)
        # group by first hop, keep the min-delay path per first hop
        best: Dict[int, List[int]] = {}
        for p in cands:
            key = p[0]
            if key not in best or _prop(p, del_a) < _prop(best[key], del_a):
                best[key] = p
        chosen = sorted(best.values(), key=lambda p: _prop(p, del_a))[:max_cand]
        if chosen:  # prune absurd detours (control-plane install policy):
            # equal-hop alternatives are always installed (that's the
            # testbed's six parallel routes); longer paths only if their
            # delay stays within detour_delay x the best.
            best_prop = _prop(chosen[0], del_a)
            best_len = min(len(p) for p in chosen)
            chosen = [p for p in chosen
                      if len(p) == best_len
                      or (len(p) <= best_len + detour_hops
                          and _prop(p, del_a) <= detour_delay * max(best_prop, 1))]
        idxs = []
        for p in chosen:
            idxs.append(len(all_paths))
            all_paths.append(p)
        pair_rows.append((s, d, idxs))

    NP = len(all_paths)
    path_links = np.full((NP, max_hops), -1, np.int32)
    path_len = np.zeros(NP, np.int32)
    for i, p in enumerate(all_paths):
        path_links[i, :len(p)] = p
        path_len[i] = len(p)
    path_prop = np.array([_prop(p, del_a) for p in all_paths], np.int32) \
        if NP else np.zeros(0, np.int32)
    path_cap = np.array([int(cap_a[p].min()) for p in all_paths], np.int32) \
        if NP else np.zeros(0, np.int32)
    path_first = np.array([p[0] for p in all_paths], np.int32) \
        if NP else np.zeros(0, np.int32)

    NPAIR = len(pair_rows)
    pair_cand = np.full((NPAIR, max_cand), -1, np.int32)
    pair_ncand = np.zeros(NPAIR, np.int32)
    pair_src = np.zeros(NPAIR, np.int32)
    pair_dst = np.zeros(NPAIR, np.int32)
    ideal_prop = np.zeros(NPAIR, np.int32)
    ideal_cap = np.zeros(NPAIR, np.int32)
    for i, (s, d, idxs) in enumerate(pair_rows):
        pair_src[i], pair_dst[i] = s, d
        pair_cand[i, :len(idxs)] = idxs
        pair_ncand[i] = len(idxs)
        if idxs:
            props = path_prop[idxs]
            j = idxs[int(np.argmin(props))]
            ideal_prop[i] = path_prop[j]
            ideal_cap[i] = path_cap[j]
    return PathTable(path_links, path_len, path_prop, path_cap, path_first,
                     pair_src, pair_dst, pair_cand, pair_ncand,
                     ideal_prop, ideal_cap)


def _prop(path_links: List[int], delays) -> int:
    return int(sum(int(delays[li]) for li in path_links))


def all_pairs(topo: Topology) -> List[Tuple[int, int]]:
    return [(s, d) for s in range(topo.num_nodes)
            for d in range(topo.num_nodes) if s != d]


def multipath_pair_fraction(table: PathTable) -> float:
    """Fraction of pairs with >1 candidate (paper §6.2: 25.6% on 13-DC)."""
    return float((table.pair_ncand > 1).mean())
