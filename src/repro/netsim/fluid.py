"""Flow-level fluid simulator for inter-DC RDMA routing (paper §6), as
one jitted ``lax.scan`` — the fast backend of the multi-engine core
(``repro.netsim.engine``; the packet-level backend is
``repro.netsim.packet``).

Model (standard fluid FCT-benchmark abstractions):
- flows arrive (Poisson, CDF-sized), are routed at arrival (per-flow
  stickiness — the paper never migrates active flows; the FatPaths/lcmp_r
  baselines may additionally re-decide on a ``redecide_period_us`` epoch
  via the shared re-decision tick), start at line rate
  (RDMA), and share links max-min-proportionally: each link scales the
  flows through it by ``min(1, cap/offered)`` and a flow sends at its
  path-min factor — so per-link service never exceeds capacity.
- per-link byte queues integrate overload ``(offered - cap)+ dt`` and
  drain otherwise (PFC-lossless: clamped at the 6 GB long-haul buffer,
  never dropped). Queues contribute waiting time to FCT (at arrival and
  completion) and are the congestion-signal source.
- congestion feedback is **RTT-delayed**: rate control reads link signals
  from ``t - RTT(path)`` via per-link history rings — the paper's
  "slow and easily outdated feedback" is modeled explicitly.
- end-host CC is a pluggable rate law (DCQCN / DCTCP / TIMELY / HPCC
  -like), all reacting to the delayed signals, MD gated by a reaction
  timer (min of one RTT and ``cc_dec_period_us``).
- the LCMP switch runs inside the loop: per-link Q/T/D registers are
  refreshed every ``dt`` (the monitor cadence) and new-flow batches run
  the exact ``repro.core`` decision path — a batch arriving in the same
  step *is* the paper's simultaneous-arrival herd case.
- the *routing* signal is propagation-faithful too: each hop's quantized
  ``C_cong`` (the ``core.cong`` register-pipeline output, stored per step
  in the ``hist_c`` ring) reaches the ingress only after the hop's
  one-way propagation distance back to it (``SimArrays.path_sig_delay``,
  scaled by ``sig_delay_scale`` for staleness ablations). The decision
  reads the max over hops of these delayed scores — never raw queue
  bytes, and never fresher than physics allows.
- the control plane is live: ``C_path`` is switch *state*, re-installed
  every ``ctrl_period_us`` from **effective** link capacities (degrade
  schedule + liveness applied) via ``core.pathq`` — the paper's §7.3
  update-period knob. ``ctrl_period_us=0`` freezes the build-time table.

Everything dynamic lives in ``SimState`` (a pytree); one ``run()`` call
lowers to a single XLA while-loop. The config/state/arrays dataclasses,
``build()``, the signal/control planes, routing and the CC laws live in
``repro.netsim.engine`` (shared with the packet engine) and are
re-exported here for compatibility.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

# Shared multi-engine core — re-exported so `fluid.X` keeps working for
# every name that predates the engine split.
from repro.netsim import engine, sanitize
from repro.netsim.engine import (  # noqa: F401
    ENGINES, HIST, POLICIES, POLICY_CODES, REDECIDE_POLICIES, _NEVER,
    SimArrays, SimConfig, SimState, _cc_update, _path_queue_wait,
    _reroute_dead, _route_arrivals, attach_link_caps, build, ctrl_refresh,
    ctrl_tick, decide, monitor_tick, path_cong_view, policy_code,
    redecide_tick, redte_tick, wants_redecide)

name = "fluid"


# --------------------------------------------------------------------- step
def make_step(ar: SimArrays, cfg: SimConfig):
    L = ar.link_cap.shape[0]
    dt = float(cfg.dt_us)
    checks_on = sanitize.enabled(cfg)

    def step(st: SimState, t):
        # 0) failure injection + lazy fast-failover (paper §3.4): at a
        # trip step, flows pinned to a dead path are treated as "first
        # packets" again and re-hashed onto live candidates. The schedule
        # lives in (L,) arrays shared across sweep cells, so the trip
        # predicate stays unbatched under vmap and the reroute cond is a
        # real branch (paid only at trip steps), not a select.
        if cfg.has_failures:
            st = dataclasses.replace(st, link_alive=t < ar.link_fail_step)
            is_trip = (ar.link_fail_step == t).any()
            st = jax.lax.cond(is_trip,
                              lambda s: _reroute_dead(t, s, ar, cfg),
                              lambda s: s, st)

        # 1) switch monitor tick + 1b) control-plane refresh (shared)
        st = monitor_tick(t, st, ar, cfg)
        st = ctrl_tick(t, st, ar, cfg)

        # 2) arrivals + routing decisions (the herd batch)
        st = _route_arrivals(t, st, ar, cfg)

        # 2b) mid-flow re-decision epoch (fluid eligibility is a timer:
        # every redecide_period_us all re-decision-capable flows may
        # re-hash). The gate is Python-level when the plane is off —
        # nothing extra is traced — and a real lax.cond branch when on
        # (t is unbatched under vmap, so off-epoch steps pay nothing).
        if wants_redecide(cfg):
            period = max(cfg.redecide_period_us // cfg.dt_us, 1)
            st = jax.lax.cond(
                (t % period) == 0,
                lambda s: redecide_tick(t, s, ar, cfg,
                                        jnp.ones_like(s.active)),
                lambda s: s, st)

        # 3) offered load per link
        pf = st.flow_path
        links_f = ar.path_links[jnp.maximum(pf, 0)]             # (F,H)
        links_ok = (links_f >= 0) & st.active[:, None] & (pf >= 0)[:, None]
        lidx = jnp.maximum(links_f, 0)
        contrib = jnp.where(links_ok, st.rate[:, None], 0.0)
        offered = jax.ops.segment_sum(contrib.reshape(-1), lidx.reshape(-1),
                                      num_segments=L)           # (L,) B/us

        # 4) per-link share factor and queue integration. Degradation is
        # *silent* (an OTN segment loses capacity but stays up): flows stay
        # pinned and only CC + the switch's congestion registers react —
        # the scenario the paper's cost model is meant to absorb.
        cap_nom = ar.link_cap
        if cfg.has_degrade:
            cap_nom = cap_nom * jnp.where(t >= ar.link_deg_step,
                                          ar.link_deg_factor, 1.0)
        cap = jnp.where(st.link_alive, cap_nom, 1e-9)
        factor_l = jnp.minimum(1.0, cap / jnp.maximum(offered, 1e-9))
        served = jnp.minimum(offered, cap)
        q = jnp.clip(st.q_bytes + (offered - cap) * dt, 0.0,
                     float(cfg.buffer_bytes * cfg.cap_scale))
        util = offered / cap
        hslot = jnp.asarray(t % HIST, jnp.int32)
        st = dataclasses.replace(
            st, q_bytes=q,
            hist_q=st.hist_q.at[:, hslot].set(
                q, mode=engine.RING_SCATTER_MODE),
            hist_u=st.hist_u.at[:, hslot].set(
                util, mode=engine.RING_SCATTER_MODE),
            u_ewma=st.u_ewma * 0.99 + 0.01 * jnp.minimum(util, 1.0),
            serv_bytes=st.serv_bytes + served * dt)

        # 5) CC rate update from delayed signals
        st = _cc_update(t, st, ar, cfg, pf, links_f, links_ok)

        # 6) drain flows at bottleneck-shared rate
        f_factor = jnp.where(links_ok, factor_l[lidx], 1.0).min(-1)
        send = jnp.where(st.active, st.rate * f_factor, 0.0)
        remaining = st.remaining - send * dt

        newly_done = st.active & (remaining <= 0)
        # completion: propagation + residual queue wait on the path
        qw_now = jnp.where(links_ok, q[lidx] / ar.link_cap[lidx], 0.0).sum(-1)
        prop = ar.path_prop[jnp.maximum(pf, 0)].astype(jnp.float32)
        fct = ((t + 1) * dt - ar.f_arr_us + prop
               + 0.5 * (st.extra_wait + qw_now))
        st = dataclasses.replace(
            st,
            remaining=jnp.maximum(remaining, 0.0),
            active=st.active & ~newly_done,
            done=st.done | newly_done,
            fct_us=jnp.where(newly_done, fct, st.fct_us))

        # 7) RedTE periodic split-ratio re-optimization (shared tick)
        st = redte_tick(t, st, ar, cfg)

        # 8) debug-mode physics invariants (Python gate: the unchecked
        # trace carries no extra ops)
        if checks_on:
            st = sanitize.step_check(t, st, ar, cfg)

        return st, None

    return step


def run_impl(arrs: SimArrays, state: SimState, cfg: SimConfig) -> SimState:
    """Unjitted scan body — the sweep engine vmaps/shard_maps this and
    wraps its own single jit around the whole batch."""
    step = make_step(arrs, cfg)
    final, _ = jax.lax.scan(step, state, jnp.arange(cfg.num_steps))
    return final


# jitted entry point for single experiments (the sweep engine jits its
# own vmap of run_impl instead, one trace per cell group)
_run_jit = jax.jit(run_impl, static_argnames=("cfg",))


def run(arrs: SimArrays, state: SimState, cfg: SimConfig) -> SimState:
    """Single-experiment entry: the plain jit, or the checkify-wrapped
    sanitizer program when ``cfg.checks`` is set (raises
    ``checkify.JaxRuntimeError`` on an invariant violation)."""
    if sanitize.enabled(cfg):
        return sanitize.run_with_checks(run_impl, arrs, state, cfg)
    return _run_jit(arrs, state, cfg)
