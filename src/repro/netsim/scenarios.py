"""Named scenario registry: topology + event schedules as one unit.

The paper's evaluation (§6) fixes two topologies; related work stresses
regimes neither expresses — FatPaths' failure/non-shortest-path regimes,
MatchRDMA's segmented long-haul OTN links. A *scenario* packages a
topology generator with optional mid-run link-failure and capacity-
degradation schedules plus a designated main traffic pair, addressable
by a single string usable anywhere an ``ExpSpec.topology`` goes::

    ExpSpec(topology="testbed8")                       # paper Fig. 1a
    ExpSpec(topology="longhaul_mesh:routes=8,segs=3")  # parameterized
    ExpSpec(topology="testbed8_failover:fail_ms=120")  # trip link mid-run

Grammar: ``name`` or ``name:key=val,key=val``. Values parse as int,
float, ``a+b+c`` integer tuples, or strings. ``scenarios.names()`` lists
everything registered; unknown names raise with that list (no raw
KeyError escapes to CLI users).

Failure semantics are the paper's lazy data-plane failover: at the trip
step pinned flows re-hash onto live candidates (``fluid._reroute_dead``).
Degradation is *silent*: the link stays up at reduced capacity and only
congestion control + the LCMP congestion registers can react — no
re-route is triggered, which is exactly the regime where cost-aware
placement should beat oblivious hashing.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, Optional, Tuple

from repro.netsim import paths as pathsmod
from repro.netsim import topo as topomod
from repro.netsim.topo import Topology


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named experiment world: topology + schedules + main pair."""
    name: str
    topology: Topology
    main_pair: Tuple[int, int]
    # ((link_idx, at_us), ...) — hard trips (lazy failover re-hash)
    fail_sched: Tuple[Tuple[int, int], ...] = ()
    # ((link_idx, at_us, factor), ...) — silent capacity loss
    degrade_sched: Tuple[Tuple[int, int, float], ...] = ()
    description: str = ""
    # the advertised traffic endpoints: (src, dst) pairs the path table is
    # built over (None = every node pair). Generators with non-DC helper
    # nodes (wan2000's OTN segment nodes) restrict this to real DC pairs.
    traffic_pairs: Optional[Tuple[Tuple[int, int], ...]] = None
    # candidate-enumeration knobs forwarded to paths.build_path_table —
    # segmented topologies count hops in *links*, so a one-haul detour is
    # `segs` extra hops and the defaults would prune every alternate route
    max_hops: int = pathsmod.MAX_HOPS
    detour_delay: float = 1.5
    detour_hops: int = 1
    # geography metadata (geo family): per-DC coordinates + metro
    # population, indexed by DC node id. traffic/sched.py derives the
    # diurnal timezone phase from dc_lon (longitude/15 deg per hour) and
    # the population-weighted traffic matrix from dc_pop; None for
    # synthetic scenarios (schedules then run unweighted, phase 0).
    dc_lat: Optional[Tuple[float, ...]] = None
    dc_lon: Optional[Tuple[float, ...]] = None
    dc_pop: Optional[Tuple[float, ...]] = None


_REGISTRY: Dict[str, Callable[..., Scenario]] = {}


def register(fn: Callable[..., Scenario]) -> Callable[..., Scenario]:
    _REGISTRY[fn.__name__] = fn
    return fn


def names():
    return sorted(_REGISTRY)


def _parse_value(v: str):
    if re.fullmatch(r"\d+(\+\d+)+", v):      # "200+100+40" -> int tuple
        return tuple(int(x) for x in v.split("+"))
    for cast in (int, float):                # handles "1e+2" etc. as float
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def parse(spec: str):
    """``"name:k=v,k2=v2"`` -> (name, {k: v, k2: v2})."""
    name, _, rest = spec.partition(":")
    params = {}
    for item in filter(None, rest.split(",")):
        k, _, v = item.partition("=")
        if not _ or not k:
            raise ValueError(f"bad scenario parameter {item!r} in {spec!r} "
                             "(expected key=value)")
        params[k] = _parse_value(v)
    return name, params


def get(spec: str) -> Scenario:
    """Resolve a scenario string to a built Scenario."""
    name, params = parse(spec)
    if name not in _REGISTRY:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"available: {', '.join(names())}")
    try:
        return _REGISTRY[name](**params)
    except TypeError as e:
        raise ValueError(f"bad parameters for scenario {name!r}: {e}") from e


def link_index(t: Topology, src: int, dst: int) -> int:
    """Directed link index for (src, dst); raises if absent."""
    for i, (s, d, _, _) in enumerate(t.links):
        if s == src and d == dst:
            return i
    raise ValueError(f"no link {src}->{dst} in {t.name}")


# ------------------------------------------------------------- the registry
@register
def testbed8() -> Scenario:
    """Paper Fig. 1a: 8-DC testbed, six heterogeneous DC1->DC8 routes."""
    return Scenario("testbed8", topomod.testbed_8dc(), main_pair=(0, 7),
                    description=testbed8.__doc__)


@register
def bso13() -> Scenario:
    """Paper §6.2: 13-DC European backbone stand-in (~26% multi-path)."""
    # (0, 6) is a 3-candidate pair (ring both ways + the 0-4 chord)
    return Scenario("bso13", topomod.bso_13dc(), main_pair=(0, 6),
                    description=bso13.__doc__)


@register
def parallel(n: int = 4, cap: int = 100, delay_ms: int = 5) -> Scenario:
    """n identical parallel long-haul routes — the symmetric null case
    where every policy should degenerate to fair hashing."""
    t = topomod.parallel_paths(caps=(cap,) * n,
                               delays_us=(delay_ms * 1000,) * n)
    return Scenario(f"parallel:n={n}", t, main_pair=(0, n + 1),
                    description=parallel.__doc__)


@register
def longhaul_mesh(routes: int = 6, segs: int = 2, caps=(200, 100, 40),
                  lo_ms: int = 5, hi_ms: int = 250) -> Scenario:
    """Parameterized parallel long-haul mesh with *segmented* OTN routes
    (MatchRDMA regime): ``routes`` parallel candidates, each a chain of
    ``segs`` spans; capacities cycle through ``caps`` (pass ``caps=200+100``
    on the CLI) and one-way delays alternate lo_ms / hi_ms per route, so
    every capacity class has a fast and a slow member like the testbed."""
    caps = caps if isinstance(caps, tuple) else (int(caps),)
    route_caps = [caps[i % len(caps)] for i in range(routes)]
    route_delays = [(lo_ms if i % 2 == 0 else hi_ms) * 1000
                    for i in range(routes)]
    t = topomod.segmented_parallel(route_caps, route_delays, segs=segs)
    return Scenario(f"longhaul_mesh:routes={routes},segs={segs}", t,
                    main_pair=(0, 1 + routes * segs),
                    description=longhaul_mesh.__doc__)


@register
def testbed8_failover(fail_ms: int = 100, link: int = 12) -> Scenario:
    """testbed8 with one long-haul link tripped mid-run (default: link 12,
    the DC1->DC5 100G/5ms haul) — drives the lazy fast-failover path."""
    return Scenario(f"testbed8_failover:fail_ms={fail_ms}",
                    topomod.testbed_8dc(), main_pair=(0, 7),
                    fail_sched=((int(link), int(fail_ms) * 1000),),
                    description=testbed8_failover.__doc__)


@register
def bso13_degrade(at_ms: int = 100, factor: float = 0.25) -> Scenario:
    """bso13 with the fat 0<->4 400G chord silently degraded to
    ``factor`` of its capacity in both directions at ``at_ms`` — the
    segmented-OTN partial-failure case where flows stay pinned and only
    congestion-aware placement of *new* flows can route around the loss."""
    t = topomod.bso_13dc()
    at = int(at_ms) * 1000
    sched = ((link_index(t, 0, 4), at, float(factor)),
             (link_index(t, 4, 0), at, float(factor)))
    return Scenario(f"bso13_degrade:at_ms={at_ms}", t, main_pair=(0, 6),
                    degrade_sched=sched,
                    description=bso13_degrade.__doc__)


@register
def staleness(deg_ms: int = 100, factor: float = 0.1,
              src: int = 2, dst: int = 7) -> Scenario:
    """Stale-signal stress family (the §7.3 ablation regime): testbed8
    main pair DC1->DC8, with the *remote* span of its good via-DC3
    candidate route — the DC3->DC8 tail hop, one 25 ms propagation away
    from the DC1 ingress — silently
    degraded to ``factor`` of its 400G at ``deg_ms``. The queue then
    builds a full one-way delay from the decision point, so placement
    quality hinges on how fresh the ingress's congestion view
    (``ExpSpec.sig_delay_scale``) and installed C_path table
    (``ExpSpec.ctrl_period_us``) are; sweep both over this scenario to
    reproduce the staleness ablation grid. (Degrading a *first* hop would
    be invisible to the ablation: the ingress reads its own egress
    registers with zero delay.)"""
    t = topomod.testbed_8dc()
    sched = ((link_index(t, int(src), int(dst)),
              int(deg_ms) * 1000, float(factor)),)
    return Scenario(f"staleness:deg_ms={deg_ms},factor={factor}", t,
                    main_pair=(0, 7), degrade_sched=sched,
                    description=staleness.__doc__)


@register
def wan2000(dcs: int = 20, segs: int = 2, chords: int = 6, seed: int = 0,
            fail_ms: int = 0, deg_ms: int = 0,
            deg_factor: float = 0.25) -> Scenario:
    """Large-scale 2000 km WAN (paper's headline scale claim, MatchRDMA's
    segmented-OTN regime): ``dcs`` DCs (20-64) on a heterogeneous ring +
    ``chords`` shortcut hauls, every haul a chain of ``segs`` OTN spans
    in the 2000 km delay class, and a testbed-style fast-fat/slow-thin
    parallel-haul main pair DC0<->DC1. Advertised traffic pairs are
    exactly the DC pairs with m in [2,8] first-hop-distinct candidates
    (segment nodes are never endpoints), so ``pairs="all"`` +
    ``bg_load`` dose a genuinely multi-path WAN. ``fail_ms``/``deg_ms``
    (optional) trip or silently degrade the fattest main-pair haul's
    first span mid-run — the span-level partial-failure case."""
    w = topomod.wan_2000km(dcs=int(dcs), segs=int(segs), chords=int(chords),
                           seed=int(seed))
    max_hops, ddelay, dhops = 2 * int(segs), 3.0, int(segs)
    dc_pairs = [(s, d) for s in w.dc_nodes for d in w.dc_nodes if s != d]
    # enumerate over ALL DC pairs to find the advertised (multi-path)
    # subset; build_world re-enumerates over just that subset so pair
    # indices stay compact — the throwaway build is numpy-cheap and paid
    # once per topology string (build_world caches)
    table = pathsmod.build_path_table(w.topology, dc_pairs,
                                      max_hops=max_hops, detour_delay=ddelay,
                                      detour_hops=dhops)
    adv = tuple((int(s), int(d)) for s, d, n in
                zip(table.pair_src, table.pair_dst, table.pair_ncand)
                if n >= 2)
    fail_sched: Tuple[Tuple[int, int], ...] = ()
    degrade_sched: Tuple[Tuple[int, int, float], ...] = ()
    li = w.main_haul_links[0]      # fattest main-pair haul, first span
    if int(fail_ms) > 0:
        fail_sched = ((li, int(fail_ms) * 1000),)
    if int(deg_ms) > 0:
        at = int(deg_ms) * 1000
        degrade_sched = ((li, at, float(deg_factor)),
                         (li + 1, at, float(deg_factor)))  # both directions
    return Scenario(f"wan2000:dcs={dcs},segs={segs}", w.topology,
                    main_pair=w.main_pair, fail_sched=fail_sched,
                    degrade_sched=degrade_sched,
                    description=wan2000.__doc__,
                    traffic_pairs=adv, max_hops=max_hops,
                    detour_delay=ddelay, detour_hops=dhops)


@register
def geo(dcs: int = 20, chords: int = 10, seed: int = 0,
        fail_ms: int = 0, deg_ms: int = 0,
        deg_factor: float = 0.25) -> Scenario:
    """Geography-grounded planetary WAN (ROADMAP item 1, MatchRDMA's
    geo-distributed OTN regime): the first ``dcs`` metros of
    ``topo.GEO_DCS`` at their real lat/lon, ring-ordered by longitude,
    every haul's delay derived from geodesic distance at ~0.67c and
    chained from 2000 km-class OTN spans. The main pair is the ring edge
    with the largest population product, carrying three parallel
    fast-fat/slow-thin hauls over progressively longer fiber routes.
    Carries per-DC lat/lon/population metadata so ``ExpSpec.load_sched``
    schedules get real timezone phase shifts and population-weighted
    traffic matrices. ``fail_ms``/``deg_ms`` trip or silently degrade the
    fattest main-pair haul's first span mid-run, as in wan2000."""
    w = topomod.geo_wan(dcs=int(dcs), chords=int(chords), seed=int(seed))
    max_hops = 2 * w.max_spans
    ddelay, dhops = 3.0, 2 * w.max_spans - 1
    dc_pairs = [(s, d) for s in w.dc_nodes for d in w.dc_nodes if s != d]
    # same two-phase enumeration as wan2000: throwaway build over all DC
    # pairs finds the advertised multi-path subset
    table = pathsmod.build_path_table(w.topology, dc_pairs,
                                      max_hops=max_hops, detour_delay=ddelay,
                                      detour_hops=dhops)
    adv = tuple((int(s), int(d)) for s, d, n in
                zip(table.pair_src, table.pair_dst, table.pair_ncand)
                if n >= 2)
    fail_sched: Tuple[Tuple[int, int], ...] = ()
    degrade_sched: Tuple[Tuple[int, int, float], ...] = ()
    li = w.main_haul_links[0]      # fattest main-pair haul, first span
    if int(fail_ms) > 0:
        fail_sched = ((li, int(fail_ms) * 1000),)
    if int(deg_ms) > 0:
        at = int(deg_ms) * 1000
        degrade_sched = ((li, at, float(deg_factor)),
                         (li + 1, at, float(deg_factor)))  # both directions
    return Scenario(f"geo:dcs={dcs},chords={chords},seed={seed}",
                    w.topology, main_pair=w.main_pair,
                    fail_sched=fail_sched, degrade_sched=degrade_sched,
                    description=geo.__doc__, traffic_pairs=adv,
                    max_hops=max_hops, detour_delay=ddelay,
                    detour_hops=dhops, dc_lat=w.dc_lat, dc_lon=w.dc_lon,
                    dc_pop=w.dc_pop)


@register
def jitter(base: str = "testbed8", frac: float = 0.2, seed: int = 0) -> Scenario:
    """Delay-asymmetry jitter over a base scenario's topology: every
    directed link's delay independently scaled by U[1-frac, 1+frac], so
    the two directions of each fiber diverge (asymmetric long-haul RTTs).
    Schedules of the base scenario are preserved."""
    b = get(str(base))
    t = topomod.delay_jitter(b.topology, frac=float(frac), seed=int(seed))
    return Scenario(f"jitter:base={base},frac={frac},seed={seed}", t,
                    main_pair=b.main_pair, fail_sched=b.fail_sched,
                    degrade_sched=b.degrade_sched,
                    description=jitter.__doc__,
                    traffic_pairs=b.traffic_pairs, max_hops=b.max_hops,
                    detour_delay=b.detour_delay, detour_hops=b.detour_hops,
                    dc_lat=b.dc_lat, dc_lon=b.dc_lon, dc_pop=b.dc_pop)
