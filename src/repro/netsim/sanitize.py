"""Debug-mode physics-invariant sanitizer for both simulation engines.

Every headline comparison this repo produces rests on the engines being
*physically right*: bytes conserved, queues non-negative and lossless,
congestion signals never fresher than backward propagation, PFC pauses
actually honored. This module makes those properties machine-checked at
runtime via ``jax.experimental.checkify``, threaded through the scan of
both ``fluid.py`` and ``packet.py``.

Off by default and **bit-for-bit free when off**: the engines consult
``enabled(cfg)`` at trace time (a Python gate on the static
``SimConfig.checks`` flag, same pattern as ``wants_redecide``), so the
unchecked program contains no extra ops (asserted for both engines in
``tests/test_sanitize.py``). Enable per experiment via
``ExpSpec(checks=1)`` or globally with ``REPRO_CHECKS=1`` in the
environment; a failed invariant raises ``checkify.JaxRuntimeError``
naming the invariant. ``benchmarks/perf.py`` records the checked-scan
overhead so this stays a debug mode, not a tax.

Three registries tie the module to the static analyzer
(reprolint INV001/INV002, ``repro.analysis.invariants``):

- ``INVARIANTS``          — invariant name -> per-step check function
- ``INVARIANT_COVERAGE``  — state field -> invariant names constraining
  it; every ``SimState``/``PacketState`` field mutated inside the scan
  must appear here or in
- ``COVERAGE_EXEMPT``     — field -> why no runtime check applies.

``tests/mutations`` installs one seeded physics bug per invariant
through the ``_MUTATION`` hook and proves each check fires on both
engines.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import checkify

from repro.netsim.engine import HIST, SimArrays, SimConfig

# test seam: (t, state) -> corrupted state, applied before the checks so
# a seeded physics bug flows onward through the scan exactly like a real
# one. None in production.
_MUTATION: Optional[Callable[[Any, Any], Any]] = None

# relative slack for f32 accumulation (per-flow byte accounting crosses
# thousands of rounded adds on ~MB quantities)
_REL_EPS = 1e-3


def enabled(cfg: SimConfig) -> bool:
    """Trace-time gate: True iff this cfg wants the checked program."""
    return bool(cfg.checks)


def host_checks_enabled() -> bool:
    """Gate for host-side (numpy) accounting checks in ``metrics`` /
    ``cosim.iterate`` — env-only, they run outside any trace."""
    return os.environ.get("REPRO_CHECKS") == "1"


def host_check(ok: bool, msg: str) -> None:
    """Host-side analogue of ``checkify.check`` (plain raise)."""
    if not ok:
        raise AssertionError(f"sanitize: {msg}")


# ------------------------------------------------------------ invariants
def _check_queue_nonneg(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Link queues and served-byte counters never go negative (the fluid
    engine clamps at 0, the packet engine only moves existing bytes)."""
    checkify.check(jnp.all(st.q_bytes >= -1e-3),
                   "queue_nonneg: negative link queue bytes")
    checkify.check(jnp.all(st.serv_bytes >= -1e-3),
                   "queue_nonneg: negative served-bytes counter")
    if hasattr(st, "fq"):
        checkify.check(jnp.all(st.fq >= -1e-3),
                       "queue_nonneg: negative per-hop flow queue")


def _check_buffer_bound(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Lossless RDMA: queue depth never exceeds the (scaled) long-haul
    buffer — the fluid clamp and the packet acceptance factors both
    enforce it, up to f32 rounding and one packet of quantization."""
    buf = float(cfg.buffer_bytes * cfg.cap_scale)
    slack = 1e-4 * buf + 2.0 * float(cfg.mtu_bytes)
    checkify.check(jnp.all(st.q_bytes <= buf + slack),
                   "buffer_bound: link queue exceeds the lossless buffer")


def _check_byte_conservation(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Per routed flow, bytes are conserved. Fluid: remaining only ever
    moves from f_size toward 0. Packet: injected = queued + delivered,
    i.e. remaining + fq.sum + delivered == f_size — the identity survives
    go-back-N failover because stranded queue bytes return to
    ``remaining`` (see ``packet._reroute_dead_packet``)."""
    routed = st.flow_path >= 0
    if hasattr(st, "fq"):
        total = st.remaining + st.fq.sum(-1) + st.delivered
        slack = _REL_EPS * ar.f_size + 2.0 * float(cfg.mtu_bytes)
        ok = jnp.abs(total - ar.f_size) <= slack
    else:
        slack = _REL_EPS * ar.f_size + 1.0
        ok = (st.remaining >= -1e-3) & (st.remaining <= ar.f_size + slack)
    checkify.check(jnp.all(jnp.where(routed, ok, True)),
                   "byte_conservation: flow byte accounting broken")


def _check_ring_head(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """The history rings' slot ``t`` holds exactly this step's state —
    an off-by-one ring slot (the classic silent-staleness bug) breaks
    the head equality immediately."""
    slot = jnp.asarray(t % HIST, jnp.int32)
    checkify.check(jnp.all(st.hist_q[:, slot] == st.q_bytes),
                   "ring_head: hist_q slot t != q_bytes (ring slot skew)")
    checkify.check(jnp.all(st.hist_c[:, slot] == st.c_cong),
                   "ring_head: hist_c slot t != c_cong (ring slot skew)")
    if hasattr(st, "hist_pause"):
        checkify.check(jnp.all(st.hist_pause[:, slot] == st.pfc_pause),
                       "ring_head: hist_pause slot t != pfc_pause")


def _check_clock_monotone(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Causality of the per-flow clocks: routing/decision timestamps
    never sit in the future, RTTs are at least one step."""
    routed = st.flow_path >= 0
    checkify.check(jnp.all(jnp.where(routed, st.route_step <= t, True)),
                   "clock_monotone: route_step in the future")
    checkify.check(jnp.all(st.last_dec <= t),
                   "clock_monotone: last CC decrease in the future")
    checkify.check(jnp.all(st.rtt_steps >= 1),
                   "clock_monotone: rtt_steps < 1")
    if hasattr(st, "last_tx"):
        checkify.check(
            jnp.all((st.last_tx <= t) | (st.last_tx == (1 << 20))),
            "clock_monotone: last_tx in the future")


def _check_signal_causality(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Routing-signal staleness offsets are non-negative (reads are
    never fresher than backward propagation delivers — paper §3) and
    inside the ring capacity the build() guard promised."""
    checkify.check(jnp.all(ar.path_sig_delay >= 0),
                   "signal_causality: negative signal delay would read "
                   "future congestion")
    checkify.check(jnp.all(ar.path_sig_delay < HIST),
                   "signal_causality: signal delay outruns the ring")


def _check_cc_rate_bounds(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Active flows send at a positive rate bounded by line rate, the
    DCTCP EWMA stays a probability, targets stay within line rate."""
    line_max = ar.path_cap.max() * 1.001
    act = st.active
    checkify.check(
        jnp.all(jnp.where(act, (st.rate > 0.0) & (st.rate <= line_max),
                          True)),
        "cc_rate_bounds: active flow rate outside (0, line]")
    checkify.check(
        jnp.all(jnp.where(act, (st.cc_target >= 0.0)
                          & (st.cc_target <= line_max), True)),
        "cc_rate_bounds: CC target outside [0, line]")
    checkify.check(jnp.all((st.cc_alpha >= 0.0) & (st.cc_alpha <= 1.0)),
                   "cc_rate_bounds: DCTCP alpha outside [0, 1]")


def _check_cong_quantized(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """Quantized switch registers stay in their wire ranges: C_cong and
    C_path in [0, 255] (the 8-bit score the paper's registers carry),
    RedTE weights in [0, 256], the utilization EWMA in [0, 1]."""
    checkify.check(jnp.all((st.c_cong >= 0) & (st.c_cong <= 255)),
                   "cong_quantized: C_cong outside [0, 255]")
    checkify.check(jnp.all((st.c_path >= 0) & (st.c_path <= 255)),
                   "cong_quantized: C_path outside [0, 255]")
    checkify.check(jnp.all((st.redte_w >= 0) & (st.redte_w <= 256)),
                   "cong_quantized: RedTE weight outside [0, 256]")
    checkify.check(jnp.all((st.u_ewma >= 0.0) & (st.u_ewma <= 1.0 + 1e-5)),
                   "cong_quantized: utilization EWMA outside [0, 1]")


def _check_completion_identity(t, st, ar: SimArrays,
                               cfg: SimConfig) -> None:
    """A flow is never both done and active, and every completed flow
    carries a positive FCT (fct >= one slot past its arrival)."""
    checkify.check(jnp.all(~(st.done & st.active)),
                   "completion_identity: flow both done and active")
    checkify.check(jnp.all(jnp.where(st.done, st.fct_us > 0.0, True)),
                   "completion_identity: completed flow with FCT <= 0")


def _check_pfc_lossless(t, st, ar: SimArrays, cfg: SimConfig) -> None:
    """PFC XOFF => no upstream forward. The hop loop's gate cannot be
    observed post-step, so this invariant is checked inline where the
    forward happens (``check_pfc`` below, called from
    ``packet.make_step`` when checks are on); registered here so the
    coverage table can reference it."""


INVARIANTS: Dict[str, Callable] = {
    "queue_nonneg": _check_queue_nonneg,
    "buffer_bound": _check_buffer_bound,
    "byte_conservation": _check_byte_conservation,
    "ring_head": _check_ring_head,
    "clock_monotone": _check_clock_monotone,
    "signal_causality": _check_signal_causality,
    "cc_rate_bounds": _check_cc_rate_bounds,
    "cong_quantized": _check_cong_quantized,
    "completion_identity": _check_completion_identity,
    "pfc_lossless": _check_pfc_lossless,
}

# state field -> invariant names that constrain it (reprolint INV001
# requires every field mutated in the scan to appear here or in
# COVERAGE_EXEMPT; INV002 cross-validates the names both ways)
INVARIANT_COVERAGE: Dict[str, Tuple[str, ...]] = {
    "flow_path": ("byte_conservation", "clock_monotone"),
    "remaining": ("byte_conservation",),
    "rate": ("cc_rate_bounds",),
    "active": ("completion_identity", "cc_rate_bounds"),
    "done": ("completion_identity",),
    "fct_us": ("completion_identity",),
    "rtt_steps": ("clock_monotone",),
    "route_step": ("clock_monotone",),
    "last_dec": ("clock_monotone",),
    "cc_alpha": ("cc_rate_bounds",),
    "cc_target": ("cc_rate_bounds",),
    "q_bytes": ("queue_nonneg", "buffer_bound", "ring_head"),
    "hist_q": ("ring_head",),
    "hist_c": ("ring_head", "cong_quantized"),
    "u_ewma": ("cong_quantized",),
    "serv_bytes": ("queue_nonneg",),
    "c_cong": ("cong_quantized", "ring_head"),
    "c_path": ("cong_quantized",),
    "redte_w": ("cong_quantized",),
    # packet engine
    "fq": ("byte_conservation", "queue_nonneg"),
    "delivered": ("byte_conservation",),
    "last_tx": ("clock_monotone",),
    "pfc_pause": ("pfc_lossless", "ring_head"),
    "hist_pause": ("pfc_lossless", "ring_head"),
}

# state field -> why no runtime invariant applies
COVERAGE_EXEMPT: Dict[str, str] = {
    "extra_wait": "FCT wait estimate derived from q_bytes/link_cap, both "
                  "already range-checked; any non-negative estimate is a "
                  "legal model output",
    "route_nonce": "hash salt for re-decision keys — every value is a "
                   "valid (deterministic) decision key",
    "prev_delay": "TIMELY gradient memory; no physical bound beyond "
                  "finiteness (it stores a delay sample or 0)",
    "hist_u": "telemetry ring; offered/cap utilization legitimately "
              "exceeds 1 under overload, so no range bound exists",
    "link_alive": "boolean liveness mask written directly from the "
                  "failure schedule comparison",
    "cong": "core register-pipeline internals (Q/T/D EWMAs); the "
            "quantized output c_cong is range-checked instead",
    "credit": "pacing accumulator bounded by the rate-BDP window of the "
              "rate at injection time; the same step's CC update may "
              "shrink that window, so no post-step bound holds",
}


# --------------------------------------------------------- step plumbing
def step_check(t, st, ar: SimArrays, cfg: SimConfig):
    """Run every registered invariant against the end-of-step state.

    Called by both engines' step functions (only when ``enabled(cfg)``,
    so the unchecked trace is untouched). The mutation seam applies
    first and its corruption flows onward through the scan — exactly how
    a real physics bug would propagate."""
    if _MUTATION is not None:
        st = _MUTATION(t, st)
    for check in INVARIANTS.values():
        check(t, st, ar, cfg)
    return st


def pfc_gate(ok_hop, paused_next):
    """The packet engine's per-hop PFC send gate (checked mode only).
    Identity in production; the pfc_lossless mutation patches this to
    ignore the pause signal, proving ``check_pfc`` catches a broken
    gate."""
    return ok_hop & ~paused_next


def check_pfc(fwd, paused_next) -> None:
    """Inline pfc_lossless check at the forward site: no bytes may be
    forwarded into a queue whose pause signal says XOFF."""
    checkify.check(jnp.all(jnp.where(paused_next, fwd <= 0.0, True)),
                   "pfc_lossless: bytes forwarded into a paused queue")


# ------------------------------------------------------------ run entry
@functools.lru_cache(maxsize=32)
def _checked_runner(run_impl: Callable, cfg: SimConfig) -> Callable:
    """jit(checkify(run_impl)) with cfg closed over (checkify's wrapper
    obscures the signature, so static_argnames cannot be used; the cache
    keys on the hashable frozen cfg instead)."""
    def run_cfg(arrs, state):
        return run_impl(arrs, state, cfg)
    return jax.jit(checkify.checkify(run_cfg,
                                     errors=checkify.user_checks))


def run_with_checks(run_impl: Callable, arrs, state, cfg: SimConfig):
    """Checked single-experiment entry: run the scan under checkify and
    throw ``checkify.JaxRuntimeError`` if any invariant failed."""
    err, final = _checked_runner(run_impl, cfg)(arrs, state)
    err.throw()
    return final


def checked_call(fn: Callable) -> Callable:
    """``jit(checkify(fn))`` with the error thrown on return — the sweep
    engine's group runner routes through this when ``cfg.checks`` is
    set, so batched cells are sanitized too."""
    checked = jax.jit(checkify.checkify(fn, errors=checkify.user_checks))

    def wrapper(*args: Any) -> Any:
        err, out = checked(*args)
        err.throw()
        return out
    return wrapper
