"""Batched serving driver: prefill + decode loop with KV caches.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3_4b --smoke \
      --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models.arch import init_params
from repro.serve.decode import decode_step, init_cache


def prefill_then_decode(cfg, params, prompt, gen_len: int):
    """Simple prefill (teacher-forced through decode steps) + decode."""
    B, S = prompt.shape
    cache = init_cache(cfg, B, S + gen_len)
    step = jax.jit(lambda p, c, t, i: decode_step(p, cfg, c, t, i))
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, prompt[:, i:i + 1], jnp.int32(i))
    toks = []
    cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for i in range(gen_len):
        toks.append(cur)
        logits, cache = step(params, cache, cur, jnp.int32(S + i))
        cur = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(toks, 1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    if cfg.family == "encdec":
        raise SystemExit("use examples/whisper_serve.py for enc-dec serving")
    params = init_params(cfg, jax.random.key(0))
    prompt = jax.random.randint(jax.random.key(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab,
                                jnp.int32)
    t0 = time.perf_counter()
    out = prefill_then_decode(cfg, params, prompt, args.gen)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    tok = args.batch * (args.prompt_len + args.gen)
    print(f"generated {out.shape} in {dt:.2f}s ({tok/dt:.1f} tok/s)")
    print(out[0, :16])


if __name__ == "__main__":
    main()
