"""End-to-end training driver.

Features exercised here (and by examples/quickstart.py):
- host-mesh sharded train loop (FSDP x TP on available devices),
- deterministic restart-safe data (step == cursor),
- atomic checkpoint (params + optimizer) + auto-resume (--resume),
  emergency save on SIGTERM,
- route telemetry: per-step wall time feeds the LCMP route trend
  registers (straggler demotion — persistently slow routes are demoted
  for *future* buckets). Explicit LCMP-scheduled cross-pod reduction
  (TrainConfig.pod_reduce = lcmp|lcmp_int8 under shard_map) is
  exercised by examples/multipod_grad_routes.py and tests/test_dist.py;
  this jit launcher lets GSPMD insert the data-parallel reduction.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3_4b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ck
"""
from __future__ import annotations

import argparse
import signal
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data.synth import batch_at
from repro.dist import lcmp_collectives as lc
from repro.dist.mesh_rules import Rules, axis_sizes_of
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ckpt
from repro.train.optim import AdamWConfig
from repro.train.step import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(args.data, args.model)
    rules = Rules(cfg, axis_sizes_of(mesh))

    tcfg = TrainConfig(optim=AdamWConfig(lr=args.lr, total_steps=args.steps),
                       microbatches=args.microbatches)
    params, opt = init_train_state(cfg, jax.random.key(0))
    start = 0
    if args.resume and args.ckpt:
        found = ckpt.latest(args.ckpt)
        if found:
            start, path = found
            restored = ckpt.restore(path, {"params": params, "opt": opt})
            params, opt = restored["params"], restored["opt"]
            print(f"[resume] step {start} from {path}")

    pspecs = rules.param_specs(params)
    ospecs = type(opt)(count=P(), mu=pspecs, nu=pspecs)
    save_specs = {"params": pspecs, "opt": ospecs}
    shard = lambda sp: jax.tree.map(lambda s: NamedSharding(mesh, s), sp,
                                    is_leaf=lambda s: isinstance(s, P))
    params = jax.device_put(params, shard(pspecs))
    opt = jax.device_put(opt, shard(ospecs))
    bspecs = rules.train_batch_specs(args.batch, args.seq)
    step_fn = jax.jit(make_train_step(cfg, tcfg))

    # emergency checkpoint on SIGTERM (preemption handling)
    state = {"params": params, "opt": opt, "step": start}

    def on_term(signum, frame):
        if args.ckpt:
            ckpt.save(args.ckpt, state["step"],
                      {"params": state["params"], "opt": state["opt"]},
                      save_specs)
            print(f"[sigterm] emergency checkpoint at step {state['step']}")
        raise SystemExit(1)

    signal.signal(signal.SIGTERM, on_term)

    with mesh:
        t_last = time.perf_counter()
        last_log = start
        for step in range(start, args.steps):
            b = batch_at(cfg, step, batch=args.batch, seq=args.seq)
            b = {k: jax.device_put(v, NamedSharding(mesh, bspecs.get(k, P())))
                 for k, v in b.items()}
            params, opt, metrics = step_fn(params, opt, b)
            state.update(params=params, opt=opt, step=step + 1)

            if (step + 1) % args.log_every == 0 or step == start:
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t_last
                t_last = time.perf_counter()
                nsteps = max(step + 1 - last_log, 1)
                last_log = step + 1
                # straggler/telemetry hook: per-step wall time (ms) ->
                # route trend registers. The first block is jit compile
                # time, not route time — don't poison the registers.
                if step != start:
                    lc._TELEMETRY.observe(
                        np.full(lc.NUM_ROUTES, int(dt * 1e3 / nsteps)),
                        int(step))
                print(f"step {step+1}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"({dt:.2f}s/{nsteps}steps)")
            if args.ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(args.ckpt, step + 1,
                          {"params": params, "opt": opt}, save_specs)
    print("done")


if __name__ == "__main__":
    main()
