"""Production mesh builders (see MULTI-POD DRY-RUN spec).

``make_production_mesh`` is a FUNCTION so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before any jax
initialization and only then calls it.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over locally available devices (tests/examples)."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[: data * model])
