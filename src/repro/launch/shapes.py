"""Assigned input-shape cells and ``input_specs()`` (ShapeDtypeStruct
stand-ins — weak-type-correct, shardable, no device allocation).

Shapes (LM family):
  train_4k     seq=4096   global_batch=256   -> train_step
  prefill_32k  seq=32768  global_batch=32    -> prefill (forward, no bwd)
  decode_32k   seq=32768(KV) global_batch=128 -> serve_step (1 new token)
  long_500k    seq=524288(KV) global_batch=1  -> serve_step; SSM/hybrid only

Applicability rules (DESIGN.md §4): long_500k is skipped for pure
full-attention archs; every arch runs the other three cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.arch import ArchConfig

F32 = jnp.float32
I32 = jnp.int32


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}

# archs allowed to run long_500k (sub-quadratic / O(1)-state decode)
LONG_OK = {"zamba2-1.2b", "falcon-mamba-7b"}


def applicable(cfg: ArchConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in LONG_OK
    return True


def skip_reason(cfg: ArchConfig, shape: str) -> Optional[str]:
    if applicable(cfg, shape):
        return None
    return ("full-attention arch: 500k-context decode requires "
            "sub-quadratic attention (DESIGN.md §4)")


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ArchConfig, cell: ShapeCell) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = cell.batch, cell.seq
    if cell.kind in ("train", "prefill"):
        batch = dict(tokens=_sds((B, S), I32), labels=_sds((B, S), I32))
        if cfg.family == "vlm":
            batch["extra"] = _sds((B, cfg.n_patches, cfg.d_model), F32)
        if cfg.family == "encdec":
            batch["extra"] = _sds((B, cfg.enc_seq, cfg.d_model), F32)
        return batch
    # decode: one new token against a seq-sized KV cache
    from repro.serve.decode import init_cache
    cache = jax.eval_shape(lambda: init_cache(cfg, B, S))
    return dict(tokens=_sds((B, 1), I32),
                pos=_sds((), I32),
                cache=cache)
