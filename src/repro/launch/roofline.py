"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), TPU v5e constants:
  t_comp = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
  t_mem  = HLO_bytes / (chips x 819e9 B/s HBM)
  t_coll = wire_bytes_per_chip / 50e9 B/s ICI   (per-link, conservative)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the post-SPMD optimized HLO
(``compiled.as_text()``) and sum result-shape bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, converting
to per-chip *wire* bytes with the standard ring-algorithm factors:
  all-reduce      2 (g-1)/g x result bytes
  all-gather      (g-1)/g x result bytes (result = gathered)
  reduce-scatter  (g-1)/g x input bytes  (= result x g)
  all-to-all      (g-1)/g x bytes
  collective-permute  1 x bytes
where g = replica-group size parsed per op.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
ICI_BW = 50e9              # B/s / link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\s]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    per_kind_bytes: Dict[str, float]
    wire_bytes_per_chip: float
    num_ops: int

    def row(self) -> str:
        return ";".join(f"{k}={v:.3e}" for k, v in
                        sorted(self.per_kind_bytes.items()))


def parse_collectives(hlo_text: str) -> CollectiveStats:
    per_kind: Dict[str, float] = {}
    wire = 0.0
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m or "-done(" in line:
            continue
        sig, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(sig)
        if nbytes == 0:
            continue
        g = _group_size(line)
        if kind == "all-reduce":
            w = 2.0 * (g - 1) / g * nbytes
        elif kind == "all-gather":
            w = (g - 1) / g * nbytes
        elif kind == "reduce-scatter":
            w = (g - 1) / g * nbytes * g      # input bytes = result x g
        elif kind == "all-to-all":
            w = (g - 1) / g * nbytes
        else:                                  # collective-permute
            w = float(nbytes)
        per_kind[kind] = per_kind.get(kind, 0.0) + nbytes
        wire += w
        n_ops += 1
    return CollectiveStats(per_kind, wire, n_ops)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return 2


def _scan_trip_count(hlo_text: str) -> int:
    """Collectives inside the depth scan execute trip_count times but the
    HLO lists them once; cost_analysis already multiplies FLOPs by trip
    count, so we scale collective bytes by the scan trip count too (the
    dominant while loop)."""
    trips = [int(t) for t in re.findall(r"trip_count=(\d+)", hlo_text)]
    return max(trips, default=1)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_wire_bytes: float
    chips: int
    t_comp: float
    t_mem: float
    t_coll: float
    bottleneck: str
    model_flops: float
    useful_ratio: float

    def derived(self) -> str:
        return (f"t_comp={self.t_comp:.3e}s;t_mem={self.t_mem:.3e}s;"
                f"t_coll={self.t_coll:.3e}s;bound={self.bottleneck};"
                f"useful={self.useful_ratio:.2f}")


def roofline(cost: dict, coll: CollectiveStats, chips: int,
             model_flops: float, scan_trips: int = 1) -> Roofline:
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    wire = coll.wire_bytes_per_chip * scan_trips
    t_comp = flops / PEAK_FLOPS
    t_mem = nbytes / HBM_BW
    t_coll = wire / ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bound = max(terms, key=terms.get)
    useful = model_flops / (flops * chips) if flops else 0.0
    return Roofline(flops=flops, hbm_bytes=nbytes, coll_wire_bytes=wire,
                    chips=chips, t_comp=t_comp, t_mem=t_mem, t_coll=t_coll,
                    bottleneck=bound, model_flops=model_flops,
                    useful_ratio=useful)


def model_flops_train(n_active: int, tokens: int) -> float:
    return 6.0 * n_active * tokens


def model_flops_decode(n_active: int, tokens: int) -> float:
    return 2.0 * n_active * tokens
