import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production mesh ((16,16) single-pod and (2,16,16) multi-pod) and
extract memory analysis, cost analysis and collective-byte footprints for
the roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

The two lines above MUST stay the first statements in this module: jax
locks the host device count at first init.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3_4b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun.jsonl
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.dist.mesh_rules import make_rules
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, input_specs, skip_reason
from repro.models.arch import forward, init_params
from repro.serve.decode import decode_step
from repro.train.optim import adamw_init
from repro.train.step import make_train_step


def _sds_tree(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _shardings(mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, P))


def lower_cell(cfg, shape_name: str, mesh, *, microbatches: int = 1,
               remat: bool = True):
    """Lower + compile one cell. Returns (compiled, lowered, meta dict)."""
    cell = SHAPES[shape_name]
    rules = make_rules(cfg, mesh)

    params_shape = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    pspecs = rules.param_specs(params_shape)
    pshard = _shardings(mesh, pspecs)

    t0 = time.perf_counter()
    if cell.kind == "train":
        from repro.train.step import TrainConfig
        tcfg = TrainConfig(microbatches=microbatches)
        step = make_train_step(cfg, tcfg)
        opt_shape = jax.eval_shape(adamw_init, params_shape)
        ospecs = type(opt_shape)(count=P(), mu=pspecs, nu=pspecs)
        oshard = _shardings(mesh, ospecs)
        bspecs = rules.train_batch_specs(cell.batch, cell.seq)
        batch_sds = input_specs(cfg, cell)
        bshard = {k: NamedSharding(mesh, bspecs[k]) for k in batch_sds}
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard,
                                    NamedSharding(mesh, P())))
        lowered = fn.lower(params_shape, opt_shape, batch_sds)
        tokens = cell.batch * cell.seq
        mflops = rl.model_flops_train(cfg.active_param_count(), tokens)
    elif cell.kind == "prefill":
        def prefill(params, batch):
            return forward(params, cfg, batch["tokens"],
                           extra=batch.get("extra"))
        bspecs = rules.train_batch_specs(cell.batch, cell.seq)
        batch_sds = input_specs(cfg, cell)
        batch_sds.pop("labels")
        bshard = {k: NamedSharding(mesh, bspecs[k]) for k in batch_sds}
        logits_spec = NamedSharding(mesh, P(bspecs["tokens"][0], None, None))
        fn = jax.jit(prefill, in_shardings=(pshard, bshard),
                     out_shardings=logits_spec)
        lowered = fn.lower(params_shape, batch_sds)
        tokens = cell.batch * cell.seq
        mflops = rl.model_flops_train(cfg.active_param_count(), tokens) / 3
    else:  # decode
        def serve(params, cache, tokens, pos):
            return decode_step(params, cfg, cache, tokens, pos)
        ins = input_specs(cfg, cell)
        cache_specs = rules.cache_specs(ins["cache"])
        cshard = _shardings(mesh, cache_specs)
        tshard = NamedSharding(mesh, rules.decode_token_spec(cell.batch))
        fn = jax.jit(serve,
                     in_shardings=(pshard, cshard, tshard,
                                   NamedSharding(mesh, P())),
                     out_shardings=(NamedSharding(mesh, P()), cshard))
        lowered = fn.lower(params_shape, ins["cache"], ins["tokens"],
                           ins["pos"])
        mflops = rl.model_flops_decode(cfg.active_param_count(), cell.batch)
    t_lower = time.perf_counter() - t0

    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0
    meta = dict(arch=cfg.name, shape=shape_name, chips=mesh.devices.size,
                t_lower_s=round(t_lower, 1), t_compile_s=round(t_compile, 1),
                model_flops=mflops)
    return compiled, lowered, meta


def _raw_measurements(compiled):
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = rl.parse_collectives(compiled.as_text())
    return dict(
        flops=float(cost.get("flops", 0.0)),
        hbm_bytes=float(cost.get("bytes accessed", 0.0)),
        coll_wire=coll.wire_bytes_per_chip,
        coll_ops=coll.num_ops,
        coll_by_kind=coll.per_kind_bytes,
        mem=dict(args=getattr(mem, "argument_size_in_bytes", 0),
                 out=getattr(mem, "output_size_in_bytes", 0),
                 temp=getattr(mem, "temp_size_in_bytes", 0)),
    )


def _depth_points(cfg):
    """Reduced-depth variants for the scan-linearity correction.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so a depth-L scan under-reports by ~L. Scan cost is exactly
    linear in depth, so two (three for enc-dec) reduced-depth compiles
    identify the affine model cost(L) = a + b*L and we extrapolate to the
    assigned depth. (Known residual: trips of *inner* sequence scans in
    the Mamba recurrence are still once-counted; their FLOPs are
    elementwise-small vs the projection matmuls, which sit outside the
    inner scans. See EXPERIMENTS.md §Dry-run notes.)
    """
    import dataclasses as dc
    # depth-1 programs remat differently (no real loop), so calibrate on
    # L = 2*step and 3*step, which sit on the affine line (verified:
    # per-layer flops delta drift < 0.5% across L=2..5).
    if cfg.family == "encdec":
        return [dc.replace(cfg, n_layers=2, n_enc_layers=2),
                dc.replace(cfg, n_layers=3, n_enc_layers=2),
                dc.replace(cfg, n_layers=2, n_enc_layers=3)]
    step = 2 if cfg.alt_local_global else 1
    return [dc.replace(cfg, n_layers=2 * step),
            dc.replace(cfg, n_layers=3 * step)]


def _extrapolate(cfg, pts, key):
    """Affine extrapolation of measurement ``key`` to the full depth."""
    if cfg.family == "encdec":
        a1, a2, a3 = [p[key] for p in pts]     # (2,2), (3,2), (2,3)
        b_dec, c_enc = a2 - a1, a3 - a1
        base = a1 - 2 * b_dec - 2 * c_enc
        return base + b_dec * cfg.n_layers + c_enc * cfg.n_enc_layers
    step = 2 if cfg.alt_local_global else 1
    a1, a2 = [p[key] for p in pts]             # L = 2*step, 3*step
    b = (a2 - a1) / step
    base = a1 - b * 2 * step
    return base + b * cfg.n_layers


def analyze(compiled, meta, depth_pts=None, cfg=None):
    raw = _raw_measurements(compiled)
    flops, nbytes, wire = raw["flops"], raw["hbm_bytes"], raw["coll_wire"]
    corrected = False
    if depth_pts is not None and cfg is not None:
        flops = _extrapolate(cfg, depth_pts, "flops")
        nbytes = _extrapolate(cfg, depth_pts, "hbm_bytes")
        wire = _extrapolate(cfg, depth_pts, "coll_wire")
        corrected = True
    coll = rl.CollectiveStats(raw["coll_by_kind"], wire, raw["coll_ops"])
    roof = rl.roofline({"flops": flops, "bytes accessed": nbytes}, coll,
                       meta["chips"], meta["model_flops"])
    out = dict(meta)
    out.update(
        bytes_per_device=dict(raw["mem"],
                              peak=raw["mem"]["args"] + raw["mem"]["temp"]),
        flops_per_device=flops,
        hbm_bytes_per_device=nbytes,
        coll_wire_bytes_per_chip=wire,
        raw_once_counted=dict(flops=raw["flops"], hbm_bytes=raw["hbm_bytes"],
                              coll_wire=raw["coll_wire"]),
        depth_corrected=corrected,
        coll_ops=raw["coll_ops"],
        coll_by_kind=raw["coll_by_kind"],
        t_comp=roof.t_comp, t_mem=roof.t_mem, t_coll=roof.t_coll,
        bottleneck=roof.bottleneck, useful_ratio=roof.useful_ratio,
    )
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3_4b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--out", default="")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-depth-correction", action="store_true",
                    help="skip the reduced-depth calibration compiles "
                         "(multi-pod pass needs compile-success only)")
    args = ap.parse_args()

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    cells = []
    if args.all:
        for a in configs.ARCH_IDS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        cells = [(args.arch, args.shape)]

    sink = open(args.out, "a") if args.out else None
    failures = 0
    for mesh_name, mesh in meshes:
        for arch, shape in cells:
            cfg = configs.get(arch)
            reason = skip_reason(cfg, shape)
            if reason:
                rec = dict(arch=arch, shape=shape, mesh=mesh_name,
                           status="skip", reason=reason)
                print(json.dumps(rec))
                if sink:
                    sink.write(json.dumps(rec) + "\n")
                    sink.flush()
                continue
            try:
                with mesh:
                    compiled, lowered, meta = lower_cell(
                        cfg, shape, mesh, microbatches=args.microbatches)
                    depth_pts = None
                    if mesh_name == "single" and not args.no_depth_correction:
                        from repro.models import arch as archmod
                        depth_pts = []
                        archmod.SCAN_UNROLL = True  # loop-free calibration
                        try:
                            for cfg_v in _depth_points(cfg):
                                c_v, _, _ = lower_cell(cfg_v, shape, mesh)
                                depth_pts.append(_raw_measurements(c_v))
                                del c_v
                        finally:
                            archmod.SCAN_UNROLL = False
                    rec = analyze(compiled, meta, depth_pts, cfg)
                    rec.update(mesh=mesh_name, status="ok")
                del compiled, lowered
            except Exception as e:  # a failure here is a sharding bug
                failures += 1
                rec = dict(arch=arch, shape=shape, mesh=mesh_name,
                           status="fail", error=f"{type(e).__name__}: {e}",
                           trace=traceback.format_exc()[-2000:])
            print(json.dumps(rec))
            if sink:
                sink.write(json.dumps(rec) + "\n")
                sink.flush()
    if sink:
        sink.close()
    if failures:
        raise SystemExit(f"{failures} dry-run cells failed")


if __name__ == "__main__":
    main()
