"""Training/network co-simulation (ROADMAP item 1): the ``repro.dist``
collective layer meets the netsim engines.

``workload`` turns a ``configs/`` model + ``launch/shapes.py`` cell +
the ``dist.lcmp_collectives`` bucket schedule into deterministic
per-iteration reduce-scatter / all-gather flow bursts overlaid on the
Poisson background (``CosimPlan`` / ``build_plan`` / ``overlay``);
``iterate`` scores the simulated run in training terms — per-iteration
makespan under barrier semantics, straggler attribution per route — and
feeds measured bucket times back into the collective layer's Q/T/D
telemetry (``feed_route_telemetry``).
"""
from repro.cosim.workload import CosimPlan, build_plan, overlay  # noqa: F401
from repro.cosim.iterate import (IterStats, feed_route_telemetry,  # noqa: F401
                                 iteration_stats, pair_path_slots,
                                 straggler_routes)
