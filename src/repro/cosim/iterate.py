"""Iteration-time metrics over a co-simulated run, and the telemetry
feedback loop into ``dist.lcmp_collectives``.

A training iteration completes when its LAST bucket flow delivers —
barrier semantics per pod: the optimizer step waits on every
reduce-scatter and all-gather bucket of the iteration, so the
iteration's makespan is the wall-clock completion of its straggler
bucket minus the iteration start. ``straggler_routes`` attributes those
waits to the simulated routes the buckets actually took, and
``feed_route_telemetry`` replays the measured per-bucket times into a
``RouteTelemetry`` register file — closing the loop the dist layer
previously faked with synthetic wall times: route demotion for future
buckets is now driven by simulated congestion.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.cosim.workload import CosimPlan
from repro.dist.lcmp_collectives import RouteTelemetry
from repro.netsim import sanitize
from repro.netsim.metrics import completion_wall_us


@dataclasses.dataclass(frozen=True)
class IterStats:
    """Per-iteration makespans of one co-simulated training run."""
    makespan_ms: np.ndarray    # (I,) float64; NaN = iteration incomplete
    iters_total: int

    @property
    def iters_done(self) -> int:
        return int(np.isfinite(self.makespan_ms).sum())

    @property
    def completion_rate(self) -> float:
        return (self.iters_done / self.iters_total if self.iters_total
                else float("nan"))

    def pct(self, q: float) -> float:
        done = self.makespan_ms[np.isfinite(self.makespan_ms)]
        return float(np.percentile(done, q)) if len(done) else float("nan")

    def pct_strict(self, q: float) -> float:
        """Percentile over ALL iterations with incomplete ones at +inf —
        the ordering metric. A policy that drops an iteration trained
        infinitely slowly that step; excluding it would let survivorship
        bias make the worst policy look fastest."""
        if not len(self.makespan_ms):
            return float("nan")
        mk = np.where(np.isfinite(self.makespan_ms), self.makespan_ms,
                      np.inf)
        # nearest-rank: interpolating adjacent ranks would compute
        # inf - inf = nan once any iteration is incomplete
        return float(np.percentile(mk, q, method="nearest"))

    @property
    def p50_ms(self) -> float:
        return self.pct(50)

    @property
    def p99_ms(self) -> float:
        return self.pct(99)


def _cosim_rows(plan: CosimPlan, flows, final):
    """(plan_idx, done, wall_us) for the co-simulated rows of a run."""
    if flows.cosim_of is None:
        raise ValueError("FlowSet has no cosim_of — was it built with "
                         "overlay()?")
    rows = np.nonzero(np.asarray(flows.cosim_of) >= 0)[0]
    pidx = np.asarray(flows.cosim_of)[rows]
    if len(pidx) != plan.num_rows:
        raise ValueError(f"flow set carries {len(pidx)} cosim rows, plan "
                         f"has {plan.num_rows}")
    wall = completion_wall_us(final, flows)[rows]
    done = np.asarray(final.done)[rows]
    return rows, pidx, done, wall


def iteration_stats(plan: CosimPlan, flows, final) -> IterStats:
    """Per-iteration makespan under barrier semantics: an iteration is
    complete iff ALL its bucket flows (both collective phases) delivered
    inside the horizon; its makespan is the straggler bucket's wall
    completion minus the iteration start."""
    _, pidx, done, wall = _cosim_rows(plan, flows, final)
    iters = plan.iter_of[pidx]
    all_done = np.ones(plan.n_iters, bool)
    np.logical_and.at(all_done, iters, done)
    last = np.zeros(plan.n_iters, np.float64)
    np.maximum.at(last, iters, np.where(done, wall, 0.0))
    mk = (last - plan.iter_start_us(np.arange(plan.n_iters))) / 1000.0
    if sanitize.host_checks_enabled():
        # barrier causality: no complete iteration finishes before it
        # starts (would mean a bucket's wall completion predates arrival)
        sanitize.host_check(bool(np.all(mk[all_done] >= 0.0)),
                            "cosim barrier: iteration completes before "
                            "its start")
    return IterStats(makespan_ms=np.where(all_done, mk, np.nan),
                     iters_total=plan.n_iters)


def straggler_routes(plan: CosimPlan, flows, final) -> Dict[int, Dict]:
    """Straggler attribution per simulated route: for each global path
    index the collective buckets landed on, the bucket count, the mean
    and max bucket completion time (ms from the bucket's own arrival),
    and how many times that route carried an iteration's straggler
    bucket. Undelivered buckets attribute to their chosen route with an
    infinite time (they ARE the straggler)."""
    rows, pidx, done, wall = _cosim_rows(plan, flows, final)
    path = np.asarray(final.flow_path)[rows]
    arr = np.asarray(flows.arrival_us)[rows]
    ms = np.where(done, (wall - arr) / 1000.0, np.inf)
    iters = plan.iter_of[pidx]
    # straggler bucket per iteration: the max completion wall (undone
    # buckets dominate via +inf)
    wall_inf = np.where(done, wall, np.inf)
    strag = np.full(plan.n_iters, -1, np.int64)
    for i in range(plan.n_iters):
        sel = np.nonzero(iters == i)[0]
        if len(sel):
            strag[i] = sel[int(np.argmax(wall_inf[sel]))]
    out: Dict[int, Dict] = {}
    for p in np.unique(path):
        m = path == p
        out[int(p)] = {
            "buckets": int(m.sum()),
            "mean_ms": float(ms[m][np.isfinite(ms[m])].mean())
            if np.isfinite(ms[m]).any() else float("inf"),
            "max_ms": float(ms[m].max()),
            "stragglers": int(sum(1 for s in strag
                                  if s >= 0 and path[s] == p)),
        }
    return out


def pair_path_slots(table, pair_id: int) -> Dict[int, int]:
    """{global path index: candidate-slot index} for one pair — the
    mapping that names each simulated route as a telemetry register."""
    out: Dict[int, int] = {}
    for k in range(int(table.pair_ncand[pair_id])):
        out[int(table.pair_cand[pair_id, k])] = k
    return out


def feed_route_telemetry(plan: CosimPlan, flows, final,
                         telemetry: RouteTelemetry,
                         path_slot: Optional[Dict[int, int]] = None,
                         table=None) -> RouteTelemetry:
    """Replay the run's measured per-bucket times into a Q/T/D register
    file, one ``observe_measured`` call per training iteration in order
    — the co-simulation feedback seam: ``schedule_buckets`` consulted
    after this demotes routes that the *simulated* network congested,
    not routes a synthetic wall clock flagged.

    ``path_slot`` maps global path index -> telemetry register (default:
    the measured pair's candidate slots via ``pair_path_slots`` when
    ``table`` is given). Buckets on unmapped paths are dropped (slot -1,
    ``observe_measured`` semantics); undelivered buckets register at the
    horizon-sized time ``2 x period`` — persistently failing routes must
    look slow, not invisible.
    """
    if path_slot is None:
        if table is None:
            raise ValueError("feed_route_telemetry needs path_slot or table")
        path_slot = pair_path_slots(table, int(plan.pair_id[0]))
    rows, pidx, done, wall = _cosim_rows(plan, flows, final)
    path = np.asarray(final.flow_path)[rows]
    arr = np.asarray(flows.arrival_us)[rows]
    ms = np.where(done, (wall - arr) / 1000.0, 2 * plan.period_us / 1000.0)
    slots = np.array([path_slot.get(int(p), -1) for p in path], np.int64)
    iters = plan.iter_of[pidx]
    for i in range(plan.n_iters):
        m = iters == i
        telemetry.observe_measured(ms[m].astype(np.int64), slots[m], step=i)
    return telemetry
