"""Collective-traffic generation: a training job as netsim flows.

``build_plan`` resolves a ``configs/`` architecture (smoke config — the
CPU-tractable same-family reduction) and a ``launch/shapes.py`` cell
into the exact bucket structure ``dist.lcmp_collectives`` would put on
the wire: the flat gradient chopped into ``BUCKET_ELEMS`` buckets, each
bucket's wire bytes under the optional int8+scales compression, one
reduce-scatter and one all-gather burst per bucket per training
iteration across ``pods`` pods. Arrival phases are fully deterministic
(no rng): reduce-scatter buckets stagger over the first quarter of the
iteration period (backward-pass readiness order), all-gather bursts
follow half a period later on the reverse pair — so the co-simulated
rows layer onto the existing Poisson background without touching its
draw sequence (see ``overlay``).

``overlay`` appends the plan's rows to a generated ``FlowSet`` AFTER
every background rng draw is complete and re-sorts by arrival with a
stable sort, so background rows keep their exact legacy values and
relative order — the bit-for-bit property the tier-1 suite pins. The
appended rows are identified by ``FlowSet.cosim_of`` (row -> plan
index, -1 for background), which is how ``cosim.iterate`` maps
simulation results back to iterations and buckets.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.dist.lcmp_collectives import BUCKET_ELEMS, _fmix32_host
from repro.dist.mesh_rules import Rules
from repro.kernels.qsr_int8 import BLOCK
from repro.launch import shapes as shapesmod
from repro.traffic.gen import FlowSet

# pods in the geo-distributed job: one per WAN endpoint of the measured
# pair (the repo's dist layer replicates parameters across pods and
# sends gradients over the long haul, mesh_rules.py)
PODS = 2
# fraction of the iteration period the backward pass spreads its
# reduce-scatter bucket bursts over (readiness order), and the offset at
# which the optimizer's all-gather burst follows
RS_SPREAD = 0.25
AG_OFFSET = 0.5

GRAD_BYTES_PER_PARAM = 4          # f32 gradients on the wire pre-compression


@dataclasses.dataclass(frozen=True)
class CosimPlan:
    """Deterministic per-bucket flow schedule for one training run."""
    model: str                 # configs arch id (alias form)
    cell: str                  # launch/shapes cell name
    n_iters: int
    n_buckets: int
    pods: int
    period_us: int             # iteration period (duration / n_iters)
    tokens_per_iter: int       # global batch x seq (cell metadata)
    param_count: int
    compressed: bool
    # flat per-flow arrays, one row per (iteration, phase, bucket)
    arrival_us: np.ndarray     # (R,) int64
    size_bytes: np.ndarray     # (R,) float64 wire bytes on the haul
    pair_id: np.ndarray        # (R,) int32
    flow_id: np.ndarray        # (R,) uint32 nonzero hash keys
    iter_of: np.ndarray        # (R,) int32
    bucket_of: np.ndarray      # (R,) int32
    phase_of: np.ndarray       # (R,) int8  0 = reduce-scatter, 1 = all-gather

    @property
    def num_rows(self) -> int:
        return len(self.arrival_us)

    def iter_start_us(self, i) -> np.ndarray:
        return np.asarray(i, np.int64) * self.period_us


@functools.lru_cache(maxsize=16)
def _smoke_param_count(model: str) -> int:
    """Parameter count of the arch's smoke config (jax.eval_shape under
    the hood — no weight allocation; cached, the registry import is the
    expensive part)."""
    from repro import configs
    return int(configs.get(model, smoke=True).param_count())


def bucket_wire_bytes(param_count: int, compressed: bool) -> np.ndarray:
    """(n_buckets,) wire bytes per gradient bucket, exactly the
    ``lcmp_collectives.lcmp_pod_reduce`` accounting: int8 + one f32
    scale per ``BLOCK`` elems when compressed, 4 B/elem otherwise."""
    total = int(param_count)
    nb = -(-total // BUCKET_ELEMS)
    lens = np.minimum((np.arange(nb, dtype=np.int64) + 1) * BUCKET_ELEMS,
                      total) - np.arange(nb, dtype=np.int64) * BUCKET_ELEMS
    if compressed:
        return lens + 4 * (-(-lens // BLOCK))
    return 4 * lens


def _reverse_pair(scen, table) -> int:
    """Pair id carrying the all-gather leg: the measured pair's reverse
    direction when advertised with candidates, else the forward pair
    (single-direction scenario tables)."""
    pidx = table.pair_index()
    fwd = pidx[scen.main_pair]
    rev = pidx.get((scen.main_pair[1], scen.main_pair[0]))
    if rev is not None and table.pair_ncand[rev] > 0:
        return int(rev)
    return int(fwd)


def build_plan(spec, scen, table) -> "CosimPlan":
    """Resolve ``spec.cosim_*`` knobs into a ``CosimPlan``.

    Pure function of the spec and world (no rng, no global state): the
    same spec always produces the same rows, which is what lets the
    sweep engine treat the cosim knobs as dynamic axes.
    """
    model = spec.cosim_model
    cell = shapesmod.SHAPES[spec.cosim_cell]
    if cell.kind != "train":
        raise ValueError(f"cosim needs a train cell, got {spec.cosim_cell!r}"
                         f" ({cell.kind})")
    n_iters = int(spec.cosim_iters)
    if n_iters < 1:
        raise ValueError(f"cosim_iters must be >= 1, got {n_iters}")
    period = spec.duration_us // n_iters
    if period < 1:
        raise ValueError(f"duration_us={spec.duration_us} too short for "
                         f"{n_iters} iterations")
    # the pod axis must actually shard the cell's global batch — the same
    # placement rule the training stack enforces (mesh_rules)
    from repro import configs
    cfg = configs.get(model, smoke=True)
    rules = Rules(cfg, {"pod": PODS, "data": 1, "model": 1})
    if rules.train_batch_specs(cell.batch, cell.seq)["tokens"][0] is None:
        raise ValueError(
            f"cell {cell.name!r} batch {cell.batch} does not shard across "
            f"{PODS} pods (mesh_rules placement)")

    params = _smoke_param_count(model)
    nb = -(-params // BUCKET_ELEMS)
    wire = bucket_wire_bytes(params, bool(spec.cosim_compress))
    # each leg moves (pods-1)/pods of the bucket across the haul (the
    # all_to_all reduce-scatter leg and the all_gather leg carry the
    # same bytes, lcmp_collectives._reduce_flat_*)
    leg_bytes = wire.astype(np.float64) * (PODS - 1) / PODS

    pidx = table.pair_index()
    rs_pair = int(pidx[scen.main_pair])
    ag_pair = _reverse_pair(scen, table)

    b = np.arange(nb, dtype=np.int64)
    # deterministic intra-burst stagger: bucket b of the backward pass
    # becomes ready at b/nb of the RS spread window
    rs_off = (b * int(period * RS_SPREAD)) // max(nb, 1)
    ag_off = int(period * AG_OFFSET) + rs_off
    bucket_ids = _fmix32_host(np.arange(nb, dtype=np.uint32) + np.uint32(1))

    arrs, sizes, pairs, fids, its, bks, phs = [], [], [], [], [], [], []
    for i in range(n_iters):
        start = i * period
        for phase, (off, pid) in enumerate(((rs_off, rs_pair),
                                            (ag_off, ag_pair))):
            arrs.append(start + off)
            sizes.append(leg_bytes)
            pairs.append(np.full(nb, pid, np.int32))
            salt = np.uint32(((2 * i + phase + 1) * 0x9E3779B9)
                             & 0xFFFFFFFF)
            fid = _fmix32_host(bucket_ids ^ salt)
            fids.append(np.where(fid == 0, np.uint32(1), fid))
            its.append(np.full(nb, i, np.int32))
            bks.append(b.astype(np.int32))
            phs.append(np.full(nb, phase, np.int8))

    return CosimPlan(
        model=model, cell=cell.name, n_iters=n_iters, n_buckets=nb,
        pods=PODS, period_us=int(period),
        tokens_per_iter=cell.batch * cell.seq, param_count=params,
        compressed=bool(spec.cosim_compress),
        arrival_us=np.concatenate(arrs).astype(np.int64),
        size_bytes=np.concatenate(sizes),
        pair_id=np.concatenate(pairs),
        flow_id=np.concatenate(fids),
        iter_of=np.concatenate(its),
        bucket_of=np.concatenate(bks),
        phase_of=np.concatenate(phs))


def overlay(fs: FlowSet, plan: CosimPlan) -> FlowSet:
    """Layer the plan's collective rows onto a generated background set.

    Runs AFTER every rng draw of ``traffic.gen.generate`` (the plan is
    rng-free), and merges with a *stable* sort on arrival time — so the
    background rows keep their exact legacy values and relative order
    bit-for-bit, and the combined set stays arrival-sorted as the
    engines require. Collective rows are foreground (they are the
    measured workload) and carry ``cosim_of`` back-references; with an
    ``amp`` subflow set they join as singleton parents so parent-level
    metrics stay well-defined.
    """
    F, R = fs.num_flows, plan.num_rows
    arrival = np.concatenate([fs.arrival_us,
                              plan.arrival_us]).astype(np.int64)
    size = np.concatenate([fs.size_bytes, plan.size_bytes])
    pair = np.concatenate([fs.pair_id,
                           plan.pair_id]).astype(np.int32)
    fid = np.concatenate([fs.flow_id, plan.flow_id]).astype(np.uint32)
    fg = np.concatenate([fs.foreground, np.ones(R, bool)])
    cosim_of = np.concatenate([np.full(F, -1, np.int32),
                               np.arange(R, dtype=np.int32)])
    subflow_of = None
    if fs.subflow_of is not None:
        base = int(fs.subflow_of.max()) + 1 if F else 0
        subflow_of = np.concatenate([
            fs.subflow_of, base + np.arange(R, dtype=np.int32)])

    order = np.argsort(arrival, kind="stable")
    pick = lambda a: a[order]
    return FlowSet(arrival_us=pick(arrival), size_bytes=pick(size),
                   pair_id=pick(pair), flow_id=pick(fid),
                   fg_mask=pick(fg),
                   subflow_of=(pick(subflow_of) if subflow_of is not None
                               else None),
                   cosim_of=pick(cosim_of),
                   dose_pair=fs.dose_pair, dose_target=fs.dose_target,
                   dose_real=fs.dose_real)
