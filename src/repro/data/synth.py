"""Deterministic synthetic token pipeline (restart-safe).

Every batch is a pure function of (seed, step, host) — after a
checkpoint/restart the loader resumes at the exact same sample stream
with zero state to persist (the step counter in the optimizer state IS
the data cursor). Per-host sharding keys the stream by process index so
hosts never read overlapping data.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.arch import ArchConfig


def batch_at(cfg: ArchConfig, step: int, *, batch: int, seq: int,
             seed: int = 0, host: int | None = None):
    h = jax.process_index() if host is None else host
    key = jax.random.fold_in(jax.random.fold_in(jax.random.key(seed), step), h)
    k1, k2 = jax.random.split(key)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab, jnp.int32)
    labels = jnp.roll(tokens, -1, axis=1).at[:, -1].set(-1)
    out = dict(tokens=tokens, labels=labels)
    if cfg.family == "vlm":
        out["extra"] = jax.random.normal(k2, (batch, cfg.n_patches,
                                              cfg.d_model), jnp.float32) * 0.02
    if cfg.family == "encdec":
        out["extra"] = jax.random.normal(k2, (batch, cfg.enc_seq,
                                              cfg.d_model), jnp.float32) * 0.02
    return out
