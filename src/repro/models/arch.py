"""Architecture definitions: one ArchConfig covers all 10 assigned
families (dense / MoE / SSM / hybrid / enc-dec / VLM). Parameters are
plain nested dicts with per-layer leaves stacked on axis 0 so the depth
loop is a single ``lax.scan`` (O(1) HLO in depth — compile-time critical
for the 512-device dry-run).

Simplifications vs the exact HF checkpoints (documented in DESIGN.md):
pre-norm only (gemma2's extra post-norms folded), untied LM heads,
no dropout. Structural features that change the *system* shape — GQA
ratios, head dims, local/global alternation, logit softcaps, qk-norm,
MoE top-k routing + capacity, Mamba1/Mamba2 state shapes, shared
attention blocks, encoder-decoder cross-attention, VLM prefix — are all
implemented.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L

# When True, depth scans trace unrolled. Used ONLY by the dry-run's
# reduced-depth calibration compiles: XLA cost_analysis counts a while
# body once regardless of trip count, so calibration needs loop-free HLO.
SCAN_UNROLL = False


def _scan(f, init, xs):
    return jax.lax.scan(f, init, xs, unroll=True if SCAN_UNROLL else 1)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None         # default d_model // n_heads
    # attention flavor
    rope_theta: float = 10_000.0
    window: Optional[int] = None           # sliding window size
    alt_local_global: bool = False         # gemma2: even layers local
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    qk_norm: bool = False
    # moe
    n_experts: int = 0
    top_k: int = 0
    # ssm
    ssm_state: int = 0
    ssm_expand: int = 2
    mamba_version: int = 2
    # hybrid (zamba2): shared attention block every k layers
    shared_attn_every: int = 0
    # encdec
    n_enc_layers: int = 0
    enc_seq: int = 1500
    # vlm
    n_patches: int = 0
    # numerics
    act_dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def adt(self):
        return jnp.dtype(self.act_dtype)

    def param_count(self) -> int:
        """Total N (for MODEL_FLOPS accounting)."""
        return sum(int(x.size) for x in jax.tree.leaves(
            jax.eval_shape(lambda: init_params(self, jax.random.key(0)))))

    def active_param_count(self) -> int:
        """Active N per token (MoE counts top_k of n_experts experts)."""
        total = self.param_count()
        if self.family != "moe" or self.n_experts == 0:
            return total
        expert = 3 * self.d_model * self.d_ff * self.n_layers
        dense_part = total - self.n_experts * expert
        return dense_part + self.top_k * expert


# ------------------------------------------------------------------- init
def _norm(key, d):
    return jnp.zeros((d,), jnp.float32)


def _dense(key, shape, scale=None):
    scale = scale if scale is not None else (1.0 / (shape[0] ** 0.5))
    return jax.random.normal(key, shape, jnp.float32) * scale


def _attn_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 7)
    D, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    p = dict(
        ln=_norm(ks[0], D),
        wq=_dense(ks[1], (D, H * hd)),
        wk=_dense(ks[2], (D, Kv * hd)),
        wv=_dense(ks[3], (D, Kv * hd)),
        wo=_dense(ks[4], (H * hd, D)),
    )
    if cfg.qk_norm:
        p["q_norm"] = _norm(ks[5], hd)
        p["k_norm"] = _norm(ks[6], hd)
    return p


def _mlp_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 4)
    D, F = cfg.d_model, cfg.d_ff
    return dict(ln=_norm(ks[0], D), w_gate=_dense(ks[1], (D, F)),
                w_up=_dense(ks[2], (D, F)), w_down=_dense(ks[3], (F, D)))


def _moe_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 5)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return dict(ln=_norm(ks[0], D), router=_dense(ks[1], (D, E)),
                w_gate=_dense(ks[2], (E, D, F)), w_up=_dense(ks[3], (E, D, F)),
                w_down=_dense(ks[4], (E, F, D)))


def _mamba_params(key, cfg: ArchConfig):
    ks = jax.random.split(key, 8)
    D = cfg.d_model
    Di = cfg.ssm_expand * D
    N = cfg.ssm_state
    if cfg.mamba_version == 1:
        dt_rank = max(D // 16, 1)
        return dict(
            ln=_norm(ks[0], D),
            in_proj=_dense(ks[1], (D, 2 * Di)),
            conv_w=_dense(ks[2], (4, Di), scale=0.5),
            x_proj=_dense(ks[3], (Di, dt_rank + 2 * N)),
            dt_proj=_dense(ks[4], (dt_rank, Di)),
            A_log=jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32),
                                   (Di, 1))),
            D_skip=jnp.ones((Di,), jnp.float32),
            out_proj=_dense(ks[5], (Di, D)),
        )
    H = Di // 64                                  # head dim P = 64
    return dict(
        ln=_norm(ks[0], D),
        in_proj=_dense(ks[1], (D, 2 * Di + 2 * N + H)),
        conv_w=_dense(ks[2], (4, Di + 2 * N), scale=0.5),
        A_log=jnp.zeros((H,), jnp.float32),
        D_skip=jnp.ones((H,), jnp.float32),
        norm_scale=_norm(ks[3], Di),
        out_proj=_dense(ks[4], (Di, D)),
    )


def _layer_params(key, cfg: ArchConfig):
    """One decoder layer of the appropriate family."""
    k1, k2 = jax.random.split(key)
    if cfg.family in ("dense", "vlm"):
        return dict(attn=_attn_params(k1, cfg), mlp=_mlp_params(k2, cfg))
    if cfg.family == "moe":
        return dict(attn=_attn_params(k1, cfg), moe=_moe_params(k2, cfg))
    if cfg.family == "ssm":
        return dict(mamba=_mamba_params(k1, cfg))
    if cfg.family == "hybrid":
        return dict(mamba=_mamba_params(k1, cfg))
    if cfg.family == "encdec":
        k3 = jax.random.fold_in(k2, 3)
        return dict(attn=_attn_params(k1, cfg), mlp=_mlp_params(k2, cfg),
                    xattn=_attn_params(k3, cfg))
    raise ValueError(cfg.family)


def init_params(cfg: ArchConfig, key):
    keys = jax.random.split(key, 8)
    layer_keys = jax.random.split(keys[0], cfg.n_layers)
    p = dict(
        embed=_dense(keys[1], (cfg.vocab, cfg.d_model), scale=1.0),
        lm_head=_dense(keys[2], (cfg.vocab, cfg.d_model)),
        final_ln=_norm(keys[3], cfg.d_model),
        layers=jax.vmap(lambda k: _layer_params(k, cfg))(layer_keys),
    )
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        p["shared_attn"] = _attn_params(keys[4], cfg)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[5], cfg.n_enc_layers)
        enc_cfg = dataclasses.replace(cfg, family="dense")
        p["enc_layers"] = jax.vmap(
            lambda k: _layer_params(k, enc_cfg))(enc_keys)
        p["enc_final_ln"] = _norm(keys[6], cfg.d_model)
    return p


# ----------------------------------------------------------------- forward
def _attn_apply(p, x, cfg: ArchConfig, *, layer_local: bool = False,
                kv_x=None, causal=True, positions=None, use_rope=True):
    """Full-sequence attention (train/prefill). kv_x: cross-attn source."""
    B, S, D = x.shape
    h = L.rms_norm(x, p["ln"])
    src = h if kv_x is None else kv_x
    q = jnp.einsum("bsd,de->bse", h, p["wq"].astype(h.dtype))
    k = jnp.einsum("bsd,de->bse", src, p["wk"].astype(h.dtype))
    v = jnp.einsum("bsd,de->bse", src, p["wv"].astype(h.dtype))
    Sk = src.shape[1]
    q = q.reshape(B, S, cfg.n_heads, cfg.hd)
    k = k.reshape(B, Sk, cfg.n_kv, cfg.hd)
    v = v.reshape(B, Sk, cfg.n_kv, cfg.hd)
    if cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])
        k = L.rms_norm(k, p["k_norm"])
    if use_rope and kv_x is None:
        pos = positions if positions is not None else jnp.arange(S)[None]
        q = L.rope(q, pos, cfg.rope_theta)
        k = L.rope(k, pos, cfg.rope_theta)
    window = cfg.window if (cfg.window and layer_local) else None
    if window and S > 2 * window and S % window == 0 and kv_x is None:
        o = L.local_block_attention(q, k, v, window=window,
                                    softcap=cfg.attn_softcap)
    else:
        o = L.gqa_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_softcap)
    o = o.reshape(B, S, cfg.n_heads * cfg.hd)
    return x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(h.dtype))


def _mlp_apply(p, x):
    h = L.rms_norm(x, p["ln"])
    return x + L.swiglu(h, p["w_gate"], p["w_up"], p["w_down"])


def _moe_apply(p, x, cfg: ArchConfig):
    h = L.rms_norm(x, p["ln"])
    return x + L.moe_block(h, p["router"], p["w_gate"], p["w_up"],
                           p["w_down"], top_k=cfg.top_k)


def _mamba_apply(p, x, cfg: ArchConfig):
    h = L.rms_norm(x, p["ln"])
    fn = L.mamba1_scan if cfg.mamba_version == 1 else L.mamba2_ssd
    return x + fn(h, p)


def _decoder_layer(cfg: ArchConfig, params, x, idx, enc=None, local=None):
    """One scanned decoder layer. ``local`` must be a *static* bool (the
    local/global alternation is handled by pair-scanning in forward())."""
    if cfg.family in ("dense", "vlm"):
        local = bool(cfg.window) if local is None else local
        x = _attn_apply(params["attn"], x, cfg, layer_local=local)
        x = _mlp_apply(params["mlp"], x)
    elif cfg.family == "moe":
        x = _attn_apply(params["attn"], x, cfg,
                        layer_local=bool(cfg.window))
        x = _moe_apply(params["moe"], x, cfg)
    elif cfg.family in ("ssm", "hybrid"):
        x = _mamba_apply(params["mamba"], x, cfg)
    elif cfg.family == "encdec":
        x = _attn_apply(params["attn"], x, cfg, use_rope=False)
        x = _attn_apply(params["xattn"], x, cfg, kv_x=enc, causal=False,
                        use_rope=False)
        x = _mlp_apply(params["mlp"], x)
    return x


def forward(params, cfg: ArchConfig, tokens, *, extra=None):
    """Training/prefill forward -> logits (B,S,V) in f32.

    ``extra``: family-specific stub inputs — vlm: (B,n_patches,D) patch
    embeddings; encdec: (B,enc_seq,D) precomputed frame embeddings.
    """
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.adt)
    if cfg.family == "dense" and cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.adt)

    if cfg.family == "vlm":
        x = jnp.concatenate([extra.astype(cfg.adt), x], axis=1)

    enc = None
    if cfg.family == "encdec":
        e = extra.astype(cfg.adt)
        def enc_layer(h, lp):
            h = _attn_apply(lp["attn"], h, cfg, causal=False, use_rope=False)
            h = _mlp_apply(lp["mlp"], h)
            return h, None
        e, _ = _scan(enc_layer, e, params["enc_layers"])
        enc = L.rms_norm(e, params["enc_final_ln"])

    shared = params.get("shared_attn")
    every = cfg.shared_attn_every

    if cfg.alt_local_global:
        # static local/global alternation: scan layer *pairs* (even layer
        # local sliding-window, odd layer global) — gemma2 style.
        def pair(carry, xs):
            h, = carry
            lp, idx = xs
            lp0 = jax.tree.map(lambda a: a[0], lp)
            lp1 = jax.tree.map(lambda a: a[1], lp)
            h = _decoder_layer(cfg, lp0, h, idx, enc=enc, local=True)
            h = _decoder_layer(cfg, lp1, h, idx, enc=enc, local=False)
            return (h,), None

        np2 = cfg.n_layers // 2
        lp_pairs = jax.tree.map(lambda a: a.reshape(np2, 2, *a.shape[1:]),
                                params["layers"])
        (x,), _ = _scan(jax.checkpoint(pair), (x,),
                        (lp_pairs, jnp.arange(np2)))
    else:
        def layer(carry, xs):
            h, = carry
            lp, idx = xs
            if shared is not None and every:
                h = jax.lax.cond(idx % every == 0,
                                 lambda v: _attn_apply(shared, v, cfg),
                                 lambda v: v, h)
            h = _decoder_layer(cfg, lp, h, idx, enc=enc)
            return (h,), None

        idxs = jnp.arange(cfg.n_layers)
        (x,), _ = _scan(jax.checkpoint(layer), (x,),
                        (params["layers"], idxs))

    if cfg.family == "vlm":
        x = x[:, cfg.n_patches:, :]

    x = L.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits
