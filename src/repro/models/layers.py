"""Model building blocks — pure-jnp, shard-friendly, bf16 activations.

All weights are f32; activations are cast to ``cfg.act_dtype`` (bf16 by
default) at block entry. Everything is written with einsum so XLA SPMD
can partition along the named mesh axes given by the spec trees in
``repro.dist.mesh_rules``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------- norms
def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# -------------------------------------------------------------------- rope
def rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, D). positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # (...,S,half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], -1).astype(x.dtype)


# --------------------------------------------------------------- attention
def _softcap(logits, cap: Optional[float]):
    if cap is None:
        return logits
    return jnp.tanh(logits / cap) * cap


def gqa_attention(q, k, v, *, causal=True, window: Optional[int] = None,
                  softcap: Optional[float] = None, q_offset=0):
    """q: (B,Sq,Hq,D), k/v: (B,Sk,Hkv,D). Hq % Hkv == 0. Returns (B,Sq,Hq,D).

    ``q_offset`` is the absolute position of q[0] (decode: Sk-1).
    ``window``: sliding-window size (None = full)."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, g, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = logits / jnp.sqrt(D).astype(jnp.float32)
    logits = _softcap(logits, softcap)

    qpos = jnp.arange(Sq)[:, None] + q_offset
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return out.reshape(B, Sq, Hq, D)


def local_block_attention(q, k, v, *, window: int,
                          softcap: Optional[float] = None):
    """Sub-quadratic sliding-window attention: keys are gathered from the
    current and previous block only (block size = window), so cost is
    O(S * 2W) instead of O(S^2). Exact for window <= block size.
    q,k,v: (B,S,H*,D) with S % window == 0."""
    B, S, Hq, D = q.shape
    _, _, Hkv, _ = k.shape
    nb = S // window
    qb = q.reshape(B, nb, window, Hq, D)
    kb = k.reshape(B, nb, window, Hkv, D)
    vb = v.reshape(B, nb, window, Hkv, D)
    # previous block (zero-padded for block 0)
    kprev = jnp.pad(kb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    vprev = jnp.pad(vb, ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))[:, :-1]
    k2 = jnp.concatenate([kprev, kb], axis=2)        # (B,nb,2W,Hkv,D)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    g = Hq // Hkv
    qg = qb.reshape(B, nb, window, Hkv, g, D)
    logits = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qg, k2).astype(jnp.float32)
    logits = logits / jnp.sqrt(D).astype(jnp.float32)
    logits = _softcap(logits, softcap)
    qpos = jnp.arange(window)[:, None] + window       # absolute within 2W
    kpos = jnp.arange(2 * window)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)
    # block 0 has no previous block: mask the zero-padding
    first = jnp.arange(2 * window)[None, :] >= window
    mask0 = mask & first
    bidx = jnp.arange(nb)
    m = jnp.where((bidx == 0)[:, None, None], mask0[None], mask[None])
    logits = jnp.where(m[None, :, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhgqk,bnkhd->bnqhgd", probs, v2)
    return out.reshape(B, S, Hq, D)


# --------------------------------------------------------------------- mlp
def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype)))
    h = h * jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))


# --------------------------------------------------------------------- moe
# Perf knob (EXPERIMENTS §Perf mixtral iteration 2): when set to a mesh
# axis name, the dispatch capacity dim is sharded on that axis (expert
# weights replicated over it) instead of TP-sharding d_ff inside experts.
# Moves the per-layer all-reduce from the (G,E,C,D) expert outputs to the
# (G,t,D) combine — ~2.5x fewer collective bytes when E doesn't divide
# the model axis (mixtral: 8 experts on 16-way TP).
MOE_CAPACITY_AXIS = None


def moe_block(x, router_w, w_gate, w_up, w_down, *, top_k: int,
              capacity_factor: float = 1.25, group_size: int = 512):
    """Top-k token-choice MoE with capacity (GShard-style grouped dispatch).

    x: (B,S,D); router_w: (D,E); expert weights: (E,D,F)/(E,F,D).
    Dispatch/combine via one-hot einsums so the experts axis shards
    cleanly (EP) and everything stays differentiable.

    Tokens are dispatched within GROUPS of ``group_size`` (GShard): with a
    single global group the one-hot dispatch tensor is (T, E, C) with
    C ~ T/E, i.e. O(T^2) memory/compute — at train_4k scale that was a
    22 TB/device disaster (see EXPERIMENTS.md §Perf iteration 1). Grouped,
    the dispatch cost is T x E x C_g with C_g ~ group_size/E: linear in T.
    """
    B, S, D = x.shape
    E = router_w.shape[-1]
    T = B * S
    gsz = min(group_size, T)
    G = T // gsz
    xt = x.reshape(G, gsz, D)
    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts = jax.lax.top_k(probs, top_k)            # (G,t,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    cap = int(capacity_factor * (gsz * top_k) / E) + 1
    onehot = jax.nn.one_hot(experts, E, dtype=jnp.float32)      # (G,t,k,E)
    pos = jnp.cumsum(onehot, axis=1) - onehot                   # pos in expert
    pos = (pos * onehot).sum(2)                                 # (G,t,E)
    keep = (pos < cap) & (onehot.sum(2) > 0)                    # (G,t,E)
    gates_e = (gate_vals[..., None] * onehot).sum(2) * keep     # (G,t,E)

    slot = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=x.dtype)
    disp = slot * keep[..., None].astype(x.dtype)               # (G,t,E,C)
    xe = jnp.einsum("gtec,gtd->gecd", disp, xt)                 # (G,E,C,D)
    if MOE_CAPACITY_AXIS:
        from jax.sharding import PartitionSpec as _P
        xe = jax.lax.with_sharding_constraint(
            xe, _P(None, None, MOE_CAPACITY_AXIS, None))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate.astype(x.dtype)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, w_up.astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", h, w_down.astype(x.dtype))

    comb = disp * gates_e[..., None].astype(x.dtype)            # (G,t,E,C)
    yt = jnp.einsum("gtec,gecd->gtd", comb, ye)
    return yt.reshape(B, S, D)


# ------------------------------------------------------------------- mamba
def mamba1_scan(x, p, *, chunk: int = 128):
    """Mamba-1 (S6) selective scan. x: (B,S,D). Params p: dict with
    in_proj (D, 2*Di), conv_w (4, Di), x_proj (Di, dt_rank+2*N),
    dt_proj (dt_rank, Di), A_log (Di, N), D_skip (Di,), out_proj (Di, D).
    Sequential scan over S in remat'd chunks (TPU: state stays in VMEM).
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    dt_rank = p["dt_proj"].shape[0]
    Di = p["A_log"].shape[0]
    N = p["A_log"].shape[1]

    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)                           # (B,S,Di)
    # depthwise causal conv, kernel 4
    k = p["conv_w"].astype(x.dtype)                             # (4, Di)
    xpad = jnp.pad(xi, ((0, 0), (3, 0), (0, 0)))
    xi = sum(xpad[:, i:i + S, :] * k[i] for i in range(4))
    xi = jax.nn.silu(xi)

    proj = jnp.einsum("bsi,ie->bse", xi, p["x_proj"].astype(x.dtype))
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt,
                                    p["dt_proj"].astype(x.dtype)))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (Di,N)

    nchunk = S // chunk

    def chunk_step(h, xs):
        xi_c, dt_c, B_c, C_c = xs      # (B,chunk,...)

        def step(h, s):
            xi_s, dt_s, B_s, C_s = s
            dA = jnp.exp(dt_s[..., None] * A)                   # (B,Di,N)
            dBx = (dt_s * xi_s)[..., None] * B_s[:, None, :]    # (B,Di,N)
            h = h * dA + dBx
            y = jnp.einsum("bin,bn->bi", h, C_s)
            return h, y

        h, ys = jax.lax.scan(
            step, h, (jnp.moveaxis(xi_c, 1, 0), jnp.moveaxis(dt_c, 1, 0),
                      jnp.moveaxis(B_c, 1, 0), jnp.moveaxis(C_c, 1, 0)))
        return h, jnp.moveaxis(ys, 0, 1)                        # (B,chunk,Di)

    h0 = jnp.zeros((B, Di, N), jnp.float32)
    xs = tuple(a.reshape(B, nchunk, chunk, -1).swapaxes(0, 1)
               for a in (xi.astype(jnp.float32), dt.astype(jnp.float32),
                         Bc.astype(jnp.float32), Cc.astype(jnp.float32)))
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, S, Di).astype(x.dtype)
    y = y + xi * p["D_skip"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))


def mamba2_ssd(x, p, *, chunk: int = 128):
    """Mamba-2 (SSD) block, chunked dual form. x: (B,S,D). Params:
    in_proj (D, 2*Di + 2*N + H), conv_w (4, Di+2*N), A_log (H,),
    D_skip (H,), norm_scale (Di,), out_proj (Di, D). Head dim P = Di/H.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    Di = p["norm_scale"].shape[0]
    H = p["A_log"].shape[0]
    P = Di // H
    N = (p["in_proj"].shape[1] - 2 * Di - H) // 2

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [Di, 2 * Di + 2 * N], axis=-1)
    k = p["conv_w"].astype(x.dtype)
    xpad = jnp.pad(xbc, ((0, 0), (3, 0), (0, 0)))
    xbc = jax.nn.silu(sum(xpad[:, i:i + S, :] * k[i] for i in range(4)))
    xi, Bc, Cc = jnp.split(xbc, [Di, Di + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + 0.0)          # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                # (H,)

    nb = S // chunk
    xh = xi.reshape(B, nb, chunk, H, P).astype(jnp.float32)
    Bh = Bc.reshape(B, nb, chunk, N).astype(jnp.float32)
    Ch = Cc.reshape(B, nb, chunk, N).astype(jnp.float32)
    dth = dt.reshape(B, nb, chunk, H)

    dA = dth * A                                                # (B,nb,c,H)
    cs = jnp.cumsum(dA, axis=2)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]           # (B,nb,c,c,H)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
    # intra-chunk (quadratic in chunk only)
    att = jnp.einsum("bncm,bnkm->bnck", Ch, Bh)                 # (B,nb,c,c)
    att = att[..., None] * L                                    # (B,nb,c,c,H)
    y_intra = jnp.einsum("bnckh,bnkh,bnkhp->bnchp", att, dth, xh)

    # chunk states + inter-chunk recurrence
    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)               # (B,nb,c,H)
    state = jnp.einsum("bncm,bnch,bnchp->bnhmp",
                       Bh, dth * decay_to_end, xh)              # (B,nb,H,N,P)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                      # (B,nb,H)

    def inter(h, s):
        st, dec = s
        h_new = h * dec[..., None, None] + st
        return h_new, h

    _, h_prev = jax.lax.scan(
        inter, jnp.zeros((B, H, N, P), jnp.float32),
        (state.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    h_prev = h_prev.swapaxes(0, 1)                              # (B,nb,H,N,P)
    decay_in = jnp.exp(cs)                                      # (B,nb,c,H)
    y_inter = jnp.einsum("bncm,bnch,bnhmp->bnchp", Ch, decay_in, h_prev)

    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + xh.reshape(B, S, H, P) * p["D_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B, S, Di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
