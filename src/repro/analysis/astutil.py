"""AST plumbing shared by the checkers: the file index, import-aware
call-graph, jit-reachability, and a small static-vs-traced dataflow.

Everything here is *heuristic but conservative in the flagging
direction*: the tracing checkers only fire on values the dataflow can
prove TRACED, so an unresolved helper call (UNKNOWN) never produces a
finding. Reachability over-approximates (defining a nested function
counts as calling it; bare-name calls resolve through explicit imports
only), which is the right bias for hazard checks — an unreachable
function is simply never inspected.

Value lattice: ``STATIC < UNKNOWN < TRACED``.

- STATIC: trace-time Python values — config dataclasses (``SimConfig``,
  the ``*Params`` families), literals, shapes (``x.shape``/``len(x)``),
  and anything derived from only those. Casting or branching on these
  inside jitted code is fine (it is how static knobs work).
- TRACED: function parameters that are (or default to) device arrays —
  the scan carry, ``SimArrays``, the step counter — and anything an
  expression derives from them.
- UNKNOWN: everything the two rules above cannot decide.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import (
    Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple,
)

STATIC, UNKNOWN, TRACED = 0, 1, 2

# parameter annotations that mean "trace-time Python value"
STATIC_PARAM_TYPES = {
    "SimConfig", "SelectParams", "PathQParams", "CongParams", "SwitchTables",
    "ExpSpec", "int", "float", "bool", "str", "bytes", "tuple", "dict",
    "np.ndarray",
}
# parameter names conventionally bound to static config in this repo
STATIC_PARAM_NAMES = {"cfg", "params", "config", "tables", "mode", "scale",
                      "policy", "name", "axis", "seed"}

# callables whose mere syntactic use marks the referenced function as
# entering a traced context (seed) — matched on the dotted suffix
_JIT_WRAPPERS = ("jit", "vmap", "pmap", "grad", "value_and_grad",
                 "shard_map", "pallas_call", "checkpoint", "remat")
_SCAN_WRAPPERS = ("scan",)
_CTRL_WRAPPERS = ("cond", "switch", "while_loop", "fori_loop", "map",
                  "associative_scan")


def dotted_name(node: ast.AST) -> Optional[str]:
    """``jax.lax.scan`` -> "jax.lax.scan"; None for non-name chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


@dataclasses.dataclass
class FuncInfo:
    qual: str                       # "outer.inner" within the module
    path: str                       # repo-relative module path
    node: ast.AST                   # FunctionDef / AsyncFunctionDef
    parent: Optional[str] = None    # enclosing function qual, if nested
    nested: List[str] = dataclasses.field(default_factory=list)
    returns_nested: Set[str] = dataclasses.field(default_factory=set)

    @property
    def key(self) -> str:
        return f"{self.path}::{self.qual}"


@dataclasses.dataclass
class ModuleInfo:
    path: str                       # repo-relative, forward slashes
    dotted: str                     # importable dotted name under the root
    tree: ast.Module
    lines: List[str]
    funcs: Dict[str, FuncInfo] = dataclasses.field(default_factory=dict)
    # local name -> ("module", dotted) | ("attr", dotted_module, attr)
    imports: Dict[str, Tuple] = dataclasses.field(default_factory=dict)


class RepoIndex:
    """Parsed view of every analyzed file plus name-resolution maps."""

    def __init__(self, root: str, files: Sequence[str]) -> None:
        self.root = root
        self.modules: Dict[str, ModuleInfo] = {}
        self.by_dotted: Dict[str, ModuleInfo] = {}
        self.funcs: Dict[str, FuncInfo] = {}
        for path in files:
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            try:
                with open(path, encoding="utf-8") as f:
                    src = f.read()
                tree = ast.parse(src, filename=rel)
            except (SyntaxError, UnicodeDecodeError, OSError):
                continue
            mod = ModuleInfo(path=rel, dotted=_dotted_of(rel), tree=tree,
                             lines=src.splitlines())
            _index_module(mod)
            self.modules[rel] = mod
            self.by_dotted[mod.dotted] = mod
            for fi in mod.funcs.values():
                self.funcs[fi.key] = fi

    # -------------------------------------------------- name resolution
    def resolve_call(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                     node: ast.AST) -> Optional[FuncInfo]:
        """Resolve a callee expression to a FuncInfo, or None."""
        if isinstance(node, ast.Name):
            fi = self._resolve_name(mod, scope, node.id)
            if fi is not None:
                return fi
            imp = mod.imports.get(node.id)
            if imp and imp[0] == "attr":
                return self._module_func(imp[1], imp[2])
            return None
        if isinstance(node, ast.Attribute) and isinstance(node.value, ast.Name):
            imp = mod.imports.get(node.value.id)
            if imp and imp[0] == "module":
                return self._module_func(imp[1], node.attr)
            if imp and imp[0] == "attr":
                # `from repro.netsim import engine; engine.decide(...)`
                return self._module_func(f"{imp[1]}.{imp[2]}", node.attr)
        return None

    def _resolve_name(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                      name: str) -> Optional[FuncInfo]:
        """Nested defs of the scope chain first, then module level."""
        s = scope
        while s is not None:
            cand = f"{s.qual}.{name}"
            if cand in mod.funcs:
                return mod.funcs[cand]
            s = mod.funcs.get(s.parent) if s.parent else None
        return mod.funcs.get(name)

    def _module_func(self, dotted: str, attr: str) -> Optional[FuncInfo]:
        target = self.by_dotted.get(dotted)
        if target is None:
            # `from repro.netsim import engine` resolves the submodule
            target = self.by_dotted.get(f"{dotted}.{attr}")
            if target is not None:
                return None      # bare module reference, not a function
            return None
        return target.funcs.get(attr)

    # -------------------------------------------------- reachability
    def seeds_and_scan_roots(self, named_seeds: Iterable[Tuple[str, str]] = ()
                             ) -> Tuple[Set[str], Set[str]]:
        """(jit seeds, scan-body roots), as FuncInfo keys.

        A function is a seed when a reference to it appears inside a call
        to a jit-like wrapper (``jax.jit(f)``, ``jax.vmap(f)``,
        ``lax.cond(p, f, g, x)``...), or when (module-suffix, name) is in
        ``named_seeds``. Scan roots are functions passed to ``lax.scan``;
        a local ``step = make_step(...)`` indirection resolves through
        ``make_step``'s returned nested def.
        """
        seeds: Set[str] = set()
        scan_roots: Set[str] = set()
        for mod in self.modules.values():
            for scope_qual, call in _iter_calls(mod):
                cal = dotted_name(call.func)
                if cal is None:
                    continue
                last = cal.rsplit(".", 1)[-1]
                is_scan = last in _SCAN_WRAPPERS
                if not (is_scan or last in _JIT_WRAPPERS
                        or last in _CTRL_WRAPPERS):
                    continue
                scope = mod.funcs.get(scope_qual) if scope_qual else None
                for arg in list(call.args) + [kw.value for kw in call.keywords]:
                    for fi in self._func_refs(mod, scope, arg):
                        seeds.add(fi.key)
                        if is_scan:
                            scan_roots.add(fi.key)
        for suffix, name in named_seeds:
            for fi in self.funcs.values():
                if fi.path.endswith(suffix) and fi.qual == name:
                    seeds.add(fi.key)
        return seeds, scan_roots

    def _func_refs(self, mod: ModuleInfo, scope: Optional[FuncInfo],
                   node: ast.AST) -> List[FuncInfo]:
        """Function objects an argument expression may denote."""
        if isinstance(node, (ast.Name, ast.Attribute)):
            fi = self.resolve_call(mod, scope, node)
            if fi is not None:
                return [fi]
            # local alias:  step = make_step(...)  ->  returned nested def
            if isinstance(node, ast.Name) and scope is not None:
                out = []
                for asg in ast.walk(scope.node):
                    if (isinstance(asg, ast.Assign)
                            and len(asg.targets) == 1
                            and isinstance(asg.targets[0], ast.Name)
                            and asg.targets[0].id == node.id
                            and isinstance(asg.value, ast.Call)):
                        maker = self.resolve_call(mod, scope, asg.value.func)
                        if maker is not None:
                            mmod = self.modules[maker.path]
                            for rn in maker.returns_nested:
                                nf = mmod.funcs.get(f"{maker.qual}.{rn}")
                                if nf is not None:
                                    out.append(nf)
                return out
        return []

    def reachable(self, seeds: Set[str]) -> Set[str]:
        """Transitive closure over call edges + nested-def containment."""
        out: Set[str] = set()
        work = [k for k in seeds if k in self.funcs]
        while work:
            key = work.pop()
            if key in out:
                continue
            out.add(key)
            fi = self.funcs[key]
            mod = self.modules[fi.path]
            for n in fi.nested:
                nk = f"{fi.path}::{fi.qual}.{n}"
                if nk in self.funcs and nk not in out:
                    work.append(nk)
            for _, call in _iter_calls_in(fi, mod):
                callee = self.resolve_call(mod, fi, call.func)
                if callee is not None and callee.key not in out:
                    work.append(callee.key)
        return out


@dataclasses.dataclass
class CheckContext:
    """Everything a checker gets: the repo root, the parsed index, and
    an optional wire-manifest path override."""
    root: str
    index: RepoIndex
    manifest_path: Optional[str] = None


def _dotted_of(rel: str) -> str:
    p = rel[:-3] if rel.endswith(".py") else rel
    if p.endswith("/__init__"):
        p = p[: -len("/__init__")]
    if p.startswith("src/"):
        p = p[4:]
    return p.replace("/", ".")


def _index_module(mod: ModuleInfo) -> None:
    """Collect function defs (with nesting), returns-nested, imports."""

    def walk(node: ast.AST, parent: Optional[FuncInfo]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = (f"{parent.qual}.{child.name}" if parent
                        else child.name)
                fi = FuncInfo(qual=qual, path=mod.path, node=child,
                              parent=parent.qual if parent else None)
                mod.funcs[qual] = fi
                if parent is not None:
                    parent.nested.append(child.name)
                walk(child, fi)
            elif isinstance(child, ast.ClassDef):
                # methods index under "Class.method"; nesting inside
                # functions keeps the enclosing qual prefix
                fake = FuncInfo(qual=(f"{parent.qual}.{child.name}" if parent
                                      else child.name),
                                path=mod.path, node=child,
                                parent=parent.qual if parent else None)
                walk(child, fake)
            else:
                walk(child, parent)

    walk(mod.tree, None)

    for fi in mod.funcs.values():
        if not isinstance(fi.node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for stmt in ast.walk(fi.node):
            if (isinstance(stmt, ast.Return)
                    and isinstance(stmt.value, ast.Name)
                    and stmt.value.id in fi.nested):
                fi.returns_nested.add(stmt.value.id)

    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod.imports[a.asname or a.name.split(".")[0]] = (
                    "module", a.name)
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for a in node.names:
                # may denote a function (`from engine import decide`) or
                # a submodule (`from repro.netsim import engine`) — the
                # RepoIndex lookup tries both interpretations
                mod.imports[a.asname or a.name] = (
                    "attr", node.module, a.name)


def _iter_calls(mod: ModuleInfo) -> Iterator[Tuple[Optional[str], ast.Call]]:
    """(enclosing function qual | None, Call node) for a whole module."""
    owner: Dict[int, Optional[str]] = {}

    def tag(node: ast.AST, qual: Optional[str]) -> None:
        for child in ast.iter_child_nodes(node):
            q = qual
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{qual}.{child.name}" if qual else child.name
            elif isinstance(child, ast.ClassDef):
                q = f"{qual}.{child.name}" if qual else child.name
            owner[id(child)] = q if isinstance(
                child, (ast.FunctionDef, ast.AsyncFunctionDef)) else qual
            tag(child, q)

    tag(mod.tree, None)
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            yield _owner_of(mod, node), node


def _owner_of(mod: ModuleInfo, node: ast.Call) -> Optional[str]:
    """Innermost function qual whose span contains the call (linenos)."""
    best: Optional[str] = None
    best_span = None
    for fi in mod.funcs.values():
        n = fi.node
        end = getattr(n, "end_lineno", n.lineno)
        if n.lineno <= node.lineno <= end:
            span = end - n.lineno
            if best_span is None or span < best_span:
                best, best_span = fi.qual, span
    return best


def _iter_calls_in(fi: FuncInfo, mod: ModuleInfo) -> Iterator[ast.Call]:
    """Call nodes belonging to ``fi``'s own body (nested defs excluded —
    they are separate FuncInfos with their own edges)."""
    nested_spans = []
    for n in fi.nested:
        nf = mod.funcs.get(f"{fi.qual}.{n}")
        if nf is not None:
            nested_spans.append((nf.node.lineno,
                                 getattr(nf.node, "end_lineno",
                                         nf.node.lineno)))
    for node in ast.walk(fi.node):
        if isinstance(node, ast.Call):
            if any(a <= node.lineno <= b for a, b in nested_spans):
                continue
            yield fi.qual, node


# ------------------------------------------------------------- dataflow
def join(*vals: int) -> int:
    return max(vals) if vals else STATIC


class ValueFlow:
    """One-function forward dataflow over the STATIC/UNKNOWN/TRACED
    lattice. Checkers subclass and override the ``on_*`` hooks, which
    fire during the statement walk with the environment live."""

    #: Attribute names whose value is static regardless of the base
    SHAPE_ATTRS = {"shape", "ndim", "dtype", "size"}

    def __init__(self, mod: ModuleInfo, fi: FuncInfo,
                 init_env: Optional[Dict[str, int]] = None) -> None:
        self.mod = mod
        self.fi = fi
        self.env: Dict[str, int] = dict(init_env or {})
        self._classify_params()

    # ------------------------------------------------------------ hooks
    def on_call(self, node: ast.Call, arg_classes: List[int]) -> None:
        pass

    def on_branch(self, node: ast.AST, test_class: int) -> None:
        pass

    def on_subscript(self, node: ast.Subscript, value_class: int,
                     index_class: int) -> None:
        pass

    # ------------------------------------------------------- main entry
    def run(self) -> Dict[str, int]:
        body = getattr(self.fi.node, "body", [])
        # two passes: loop-carried names settle on the second
        for _ in range(2):
            for stmt in body:
                self._stmt(stmt)
        return self.env

    # ---------------------------------------------------------- helpers
    def _classify_params(self) -> None:
        node = self.fi.node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        args = node.args
        # params with a literal default (None/True/False/0/"s") are
        # static flags in this codebase, not traced arrays
        has_const_default: Dict[str, bool] = {}
        pos = list(args.posonlyargs) + list(args.args)
        for a, d in zip(reversed(pos), reversed(args.defaults)):
            has_const_default[a.arg] = isinstance(d, ast.Constant)
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is not None:
                has_const_default[a.arg] = isinstance(d, ast.Constant)
        for a in (pos + list(args.kwonlyargs)
                  + ([args.vararg] if args.vararg else [])
                  + ([args.kwarg] if args.kwarg else [])):
            cls = TRACED
            ann_names = set()
            if a.annotation is not None:
                for n in ast.walk(a.annotation):
                    if isinstance(n, ast.Name):
                        ann_names.add(n.id)
                    elif isinstance(n, ast.Attribute):
                        ann_names.add(n.attr)
                        # np.ndarray is host data even under jit
                        if isinstance(n.value, ast.Name) and \
                                n.value.id in ("np", "numpy"):
                            ann_names.add("np.ndarray")
                    elif isinstance(n, ast.Constant) and \
                            isinstance(n.value, str):
                        ann_names.add(n.value)
            if ann_names & STATIC_PARAM_TYPES:
                cls = STATIC          # incl. Optional[int] etc.
            elif a.arg in STATIC_PARAM_NAMES:
                cls = STATIC
            elif has_const_default.get(a.arg):
                cls = STATIC
            self.env[a.arg] = cls

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            self.env[stmt.name] = STATIC     # the function object itself
            return                           # body analyzed separately
        if isinstance(stmt, ast.Assign):
            cls = self.expr(stmt.value)
            for tgt in stmt.targets:
                self._bind(tgt, cls)
        elif isinstance(stmt, ast.AugAssign):
            cls = self.expr(stmt.value)
            if isinstance(stmt.target, ast.Name):
                self.env[stmt.target.id] = join(
                    self.env.get(stmt.target.id, STATIC), cls)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._bind(stmt.target, self.expr(stmt.value))
        elif isinstance(stmt, (ast.If, ast.While)):
            tc = self.expr(stmt.test)
            self.on_branch(stmt, tc)
            for s in stmt.body + stmt.orelse:
                self._stmt(s)
        elif isinstance(stmt, ast.For):
            it = self.expr(stmt.iter)
            self._bind(stmt.target, self._iter_elem_class(stmt.iter, it))
            for s in stmt.body + stmt.orelse:
                self._stmt(s)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self.expr(item.context_expr)
            for s in stmt.body:
                self._stmt(s)
        elif isinstance(stmt, ast.Try):
            for s in (stmt.body + stmt.orelse + stmt.finalbody
                      + [h for hh in stmt.handlers for h in hh.body]):
                self._stmt(s)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self.expr(stmt.value)
        elif isinstance(stmt, ast.Expr):
            self.expr(stmt.value)
        elif isinstance(stmt, (ast.Raise, ast.Assert)):
            for v in ast.iter_child_nodes(stmt):
                if isinstance(v, ast.expr):
                    self.expr(v)

    def _bind(self, target: ast.expr, cls: int) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = cls
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt, cls)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, cls)
        # attribute/subscript targets: no env effect

    def _iter_elem_class(self, iter_node: ast.expr, iter_cls: int) -> int:
        d = dotted_name(iter_node.func) if isinstance(iter_node, ast.Call) \
            else None
        if d in ("range", "enumerate", "zip"):
            if isinstance(iter_node, ast.Call):
                return join(*[self.expr(a) for a in iter_node.args]) \
                    if iter_node.args else STATIC
        return iter_cls

    # ------------------------------------------------- expression rules
    def expr(self, node: ast.expr) -> int:
        if isinstance(node, ast.Constant):
            return STATIC
        if isinstance(node, ast.Name):
            return self.env.get(node.id, STATIC)   # globals/consts: static
        if isinstance(node, ast.Attribute):
            if node.attr in self.SHAPE_ATTRS:
                self.expr(node.value)
                return STATIC
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            vc = self.expr(node.value)
            ic = self.expr(node.slice)
            self.on_subscript(node, vc, ic)
            return join(vc, ic)
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.BinOp):
            return join(self.expr(node.left), self.expr(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return join(*[self.expr(v) for v in node.values])
        if isinstance(node, ast.Compare):
            return join(self.expr(node.left),
                        *[self.expr(c) for c in node.comparators])
        if isinstance(node, ast.IfExp):
            return join(self.expr(node.test), self.expr(node.body),
                        self.expr(node.orelse))
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return join(*[self.expr(e) for e in node.elts]) \
                if node.elts else STATIC
        if isinstance(node, ast.Dict):
            vals = [v for v in list(node.keys) + list(node.values)
                    if v is not None]
            return join(*[self.expr(v) for v in vals]) if vals else STATIC
        if isinstance(node, ast.Slice):
            parts = [p for p in (node.lower, node.upper, node.step)
                     if p is not None]
            return join(*[self.expr(p) for p in parts]) if parts else STATIC
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp)):
            for gen in node.generators:
                self.expr(gen.iter)
            return UNKNOWN
        if isinstance(node, ast.DictComp):
            for gen in node.generators:
                self.expr(gen.iter)
            return UNKNOWN
        if isinstance(node, ast.Lambda):
            return STATIC
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, ast.JoinedStr):
            return STATIC
        if isinstance(node, ast.FormattedValue):
            self.expr(node.value)
            return STATIC
        if isinstance(node, ast.NamedExpr):
            cls = self.expr(node.value)
            self._bind(node.target, cls)
            return cls
        return UNKNOWN

    def _call(self, node: ast.Call) -> int:
        arg_classes = [self.expr(a) for a in node.args]
        kw_classes = [self.expr(kw.value) for kw in node.keywords]
        self.on_call(node, arg_classes)
        d = dotted_name(node.func)
        allc = arg_classes + kw_classes
        if d is not None:
            root = d.split(".", 1)[0]
            if d == "len" or d.endswith(".len"):
                return STATIC
            if root in ("jnp", "jax", "lax", "np", "numpy") or d in (
                    "float", "int", "bool", "str", "abs", "max", "min",
                    "round", "sum", "range", "tuple", "list", "dict",
                    "sorted", "enumerate", "zip", "divmod", "pow"):
                return join(*allc) if allc else STATIC
        if isinstance(node.func, ast.Attribute):
            # method call: classification follows the receiver + args
            return join(self.expr(node.func.value), *allc) \
                if allc else self.expr(node.func.value)
        return UNKNOWN
