"""RNG001/RNG002: history-ring indexing discipline.

The engine keeps per-link history rings (``hist_c``/``hist_q``/
``hist_u``/``hist_pause``) of depth ``HIST`` and addresses them with
wrapped slots (``t % HIST``, ``(t - delay) % HIST``). A read whose slot
is *not* wrapped does not crash — it aliases once the offset outgrows
the ring, which is exactly the silent-staleness bug class the build-time
guard (``max offset >= HIST -> raise``) exists to prevent.

RNG001 flags any subscript into a ring (or a local alias of one, e.g.
``pause_flat = hist_pause.reshape(-1)``) whose index expression neither
contains a literal ``% HIST`` nor references a wrapped local. Constant
indices are exempt — a fixed slot cannot outgrow the ring.

RNG002 fires once per run when ring names are used anywhere but no
build-time capacity guard (an ``if`` comparing against ``HIST`` whose
body raises) exists in the analyzed files.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.astutil import CheckContext, FuncInfo, ModuleInfo, RepoIndex
from repro.analysis.findings import Finding

RING_NAMES = ("hist_c", "hist_q", "hist_u", "hist_pause")


def _mentions_ring(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in RING_NAMES:
            return True
        if isinstance(n, ast.Attribute) and n.attr in RING_NAMES:
            return True
    return False


def _mentions_any(node: ast.AST, names: Set[str]) -> bool:
    return any(isinstance(n, ast.Name) and n.id in names
               for n in ast.walk(node))


def _has_mod_hist(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mod):
            r = n.right
            if isinstance(r, ast.Name) and r.id == "HIST":
                return True
            if isinstance(r, ast.Attribute) and r.attr == "HIST":
                return True
    return False


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _wrapped_locals(fn: ast.AST) -> Set[str]:
    """Locals provably derived from a ``% HIST`` wrap, to a fixpoint."""
    wrapped: Set[str] = set()
    for _ in range(4):
        before = len(wrapped)
        for stmt in ast.walk(fn):
            if not isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                continue
            value = stmt.value
            if value is None:
                continue
            if _has_mod_hist(value) or _mentions_any(value, wrapped):
                targets = stmt.targets if isinstance(stmt, ast.Assign) \
                    else [stmt.target]
                for t in targets:
                    for n in ast.walk(t):
                        if isinstance(n, ast.Name):
                            wrapped.add(n.id)
        if len(wrapped) == before:
            break
    return wrapped


def _ring_aliases(fn: ast.AST) -> Set[str]:
    """Locals assigned from an expression that mentions a ring but does
    not subscript it (e.g. ``flat = st.hist_c.reshape(-1)``)."""
    aliases: Set[str] = set()
    for stmt in ast.walk(fn):
        if isinstance(stmt, ast.Assign) and _mentions_ring(stmt.value):
            if not any(isinstance(n, ast.Subscript)
                       for n in ast.walk(stmt.value)):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        aliases.add(t.id)
    return aliases


def _check_function(mod: ModuleInfo, fi: FuncInfo,
                    findings: List[Finding]) -> None:
    fn = fi.node
    wrapped = _wrapped_locals(fn)
    aliases = _ring_aliases(fn)

    for node in ast.walk(fn):
        if not isinstance(node, ast.Subscript):
            continue
        base = node.value
        is_ring = _mentions_ring(base) or _mentions_any(base, aliases)
        if not is_ring:
            continue
        idx = node.slice
        idx_names = _names_in(idx) - {"HIST", "jnp", "jax", "np", "lax"}
        if not idx_names:
            continue                      # constant slot: cannot outgrow
        if _has_mod_hist(idx) or (idx_names & wrapped):
            continue
        findings.append(Finding(
            code="RNG001", path=mod.path, line=node.lineno,
            message=f"ring subscript in `{fi.qual}` indexes a history "
                    f"ring without a `% HIST` wrap — reads alias "
                    f"silently once the offset outgrows the ring"))


def _has_capacity_guard(mod: ModuleInfo) -> bool:
    """An ``if`` comparing something against HIST whose body raises."""
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.If):
            continue
        test_names = _names_in(node.test) | {
            n.attr for n in ast.walk(node.test)
            if isinstance(n, ast.Attribute)}
        if "HIST" not in test_names:
            continue
        if not any(isinstance(n, ast.Compare)
                   for n in ast.walk(node.test)) and \
                not isinstance(node.test, ast.Compare):
            continue
        if any(isinstance(s, ast.Raise) for b in [node.body]
               for s in ast.walk(ast.Module(body=b, type_ignores=[]))):
            return True
    return False


def check_rings(ctx: CheckContext) -> List[Finding]:
    index: RepoIndex = ctx.index
    findings: List[Finding] = []
    rings_used = False
    guard_found = False
    guard_mods: List[str] = []
    for mod in index.modules.values():
        uses = _mentions_ring(mod.tree)
        if uses:
            rings_used = True
            for fi in mod.funcs.values():
                if isinstance(fi.node, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)):
                    _check_function(mod, fi, findings)
        if _has_capacity_guard(mod):
            guard_found = True
            guard_mods.append(mod.path)
    if rings_used and not guard_found:
        findings.append(Finding(
            code="RNG002", path="", line=0,
            message="history rings are used but no build-time capacity "
                    "guard (`if <max offset> >= HIST: raise`) exists — "
                    "ring wraps are only sound when build() validates "
                    "every RTT / signal-delay offset against HIST"))
    # dedupe (nested functions are walked by their parents too)
    seen = set()
    out = []
    for f in findings:
        k = (f.code, f.path, f.line)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
