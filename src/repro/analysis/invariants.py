"""INV001/INV002: the static half of the runtime sanitizer contract.

``repro.netsim.sanitize`` holds three registries as module-level dict
literals — ``INVARIANTS`` (name -> checkify predicate),
``INVARIANT_COVERAGE`` (state field -> invariant names that constrain
it) and ``COVERAGE_EXEMPT`` (state field -> why no runtime check
applies). This checker closes the loop statically so the sanitizer can
never silently rot as the engines grow:

- INV001: a ``SimState``/``PacketState`` field is mutated inside the
  scan (a ``dataclasses.replace`` keyword in scan-reachable code) but
  appears in neither registry — new state slipped in without anyone
  deciding what physical law constrains it.
- INV002: registry rot — a coverage/exemption key that is not a state
  field, or a coverage entry naming an invariant that does not exist.

Silent when the analyzed files define no state classes (fixture trees,
partial file sets).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.astutil import CheckContext, RepoIndex
from repro.analysis.findings import Finding
from repro.analysis.tracing import NAMED_SEEDS

STATE_CLASSES = ("SimState", "PacketState")
_REGISTRIES = ("INVARIANTS", "INVARIANT_COVERAGE", "COVERAGE_EXEMPT")


def _state_fields(index: RepoIndex) -> Set[str]:
    fields: Set[str] = set()
    for mod in index.modules.values():
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ClassDef) and \
                    node.name in STATE_CLASSES:
                for stmt in node.body:
                    if isinstance(stmt, ast.AnnAssign) and \
                            isinstance(stmt.target, ast.Name):
                        fields.add(stmt.target.id)
    return fields


def _registries(index: RepoIndex
                ) -> Dict[str, List[Tuple[str, str, int, List[str]]]]:
    """name -> [(key, path, line, value-names)] over all dict literals
    assigned to the registry names at module level."""
    out: Dict[str, List[Tuple[str, str, int, List[str]]]] = {
        n: [] for n in _REGISTRIES}
    for mod in index.modules.values():
        for stmt in mod.tree.body:
            # plain or annotated module-level assignment of a dict literal
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                target = stmt.targets[0]
            elif isinstance(stmt, ast.AnnAssign):
                target = stmt.target
            else:
                continue
            if not (isinstance(target, ast.Name)
                    and target.id in _REGISTRIES
                    and isinstance(stmt.value, ast.Dict)):
                continue
            reg = target.id
            for k, v in zip(stmt.value.keys, stmt.value.values):
                if not (isinstance(k, ast.Constant)
                        and isinstance(k.value, str)):
                    continue
                vnames: List[str] = []
                if isinstance(v, (ast.Tuple, ast.List)):
                    vnames = [e.value for e in v.elts
                              if isinstance(e, ast.Constant)
                              and isinstance(e.value, str)]
                elif isinstance(v, ast.Constant) and \
                        isinstance(v.value, str) and \
                        reg == "INVARIANT_COVERAGE":
                    vnames = [v.value]
                out[reg].append((k.value, mod.path, k.lineno, vnames))
    return out


def _scan_mutations(index: RepoIndex,
                    fields: Set[str]) -> List[Tuple[str, str, int]]:
    """(field, path, line) for every state field passed as a keyword to
    ``dataclasses.replace`` inside scan-reachable code."""
    _, scan_roots = index.seeds_and_scan_roots(NAMED_SEEDS)
    reach = index.reachable({k for k in scan_roots if k in index.funcs})
    out: List[Tuple[str, str, int]] = []
    for key in sorted(reach):
        fi = index.funcs[key]
        for node in ast.walk(fi.node):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            is_replace = (isinstance(f, ast.Attribute)
                          and f.attr == "replace") or \
                         (isinstance(f, ast.Name) and f.id == "replace")
            if not is_replace:
                continue
            kws = {kw.arg for kw in node.keywords if kw.arg}
            if not kws & fields:
                continue           # replace() on a non-state dataclass
            for fname in sorted(kws & fields):
                out.append((fname, fi.path, node.lineno))
    return out


def check_invariants(ctx: CheckContext) -> List[Finding]:
    index: RepoIndex = ctx.index
    fields = _state_fields(index)
    if not fields:
        return []
    regs = _registries(index)
    covered = {k for k, _, _, _ in regs["INVARIANT_COVERAGE"]}
    exempt = {k for k, _, _, _ in regs["COVERAGE_EXEMPT"]}
    inv_names = {k for k, _, _, _ in regs["INVARIANTS"]}

    findings: List[Finding] = []
    flagged: Set[str] = set()
    for fname, path, line in _scan_mutations(index, fields):
        if fname in covered or fname in exempt or fname in flagged:
            continue
        flagged.add(fname)
        findings.append(Finding(
            code="INV001", path=path, line=line,
            message=f"state field `{fname}` is mutated in the scan but "
                    f"has no registered runtime invariant "
                    f"(INVARIANT_COVERAGE) and no exemption "
                    f"(COVERAGE_EXEMPT) in repro.netsim.sanitize"))

    for reg in ("INVARIANT_COVERAGE", "COVERAGE_EXEMPT"):
        for k, path, line, vnames in regs[reg]:
            if k not in fields:
                findings.append(Finding(
                    code="INV002", path=path, line=line,
                    message=f"{reg} key `{k}` is not a SimState/"
                            f"PacketState field — stale registry entry"))
            for v in vnames:
                if v not in inv_names:
                    findings.append(Finding(
                        code="INV002", path=path, line=line,
                        message=f"{reg}[`{k}`] names invariant `{v}` "
                                f"which is not in INVARIANTS"))

    seen: Set[Tuple[str, str, int, str]] = set()
    out: List[Finding] = []
    for f in findings:
        key = (f.code, f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out
