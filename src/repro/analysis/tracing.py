"""TRC001-TRC004: JAX tracing hazards inside jit-reachable code.

Reachability is seeded from the engine entry points (``run_impl`` in
``fluid.py``/``packet.py``) plus any function syntactically handed to a
jit-like wrapper (``jax.jit``/``vmap``/``shard_map``/``lax.cond``/...).
Scan bodies — functions passed to ``lax.scan``, resolved through the
``step = make_step(...)`` indirection — additionally activate TRC003.

The dataflow only flags values it can prove TRACED (see astutil), so
static config reads (``cfg.dt_us``) and unresolved helpers never fire.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (
    TRACED, CheckContext, FuncInfo, ModuleInfo, RepoIndex, ValueFlow,
    dotted_name,
)
from repro.analysis.findings import Finding

# engine entry points that are jitted by callers outside the AST's view
NAMED_SEEDS: Tuple[Tuple[str, str], ...] = (
    ("netsim/fluid.py", "run_impl"),
    ("netsim/packet.py", "run_impl"),
)

_CAST_FUNCS = {"float", "int", "bool"}
_NP_CASTS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_NP_CTORS = {"array", "asarray", "zeros", "ones", "full", "empty",
             "arange", "linspace", "eye"}
_SCATTER_METHODS = {"set", "add", "multiply", "mul", "divide", "div",
                    "power", "min", "max", "apply"}


class _TracingFlow(ValueFlow):
    def __init__(self, mod: ModuleInfo, fi: FuncInfo,
                 init_env: Optional[Dict[str, int]],
                 in_scan: bool, findings: List[Finding]) -> None:
        super().__init__(mod, fi, init_env)
        self.in_scan = in_scan
        self.findings = findings

    def _emit(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            code=code, path=self.mod.path,
            line=getattr(node, "lineno", 0),
            message=f"{msg} [in `{self.fi.qual}`]"))

    # ---------------------------------------------------------- hooks
    def on_call(self, node: ast.Call, arg_classes: List[int]) -> None:
        d = dotted_name(node.func)
        if d is not None:
            if (d in _CAST_FUNCS or d in _NP_CASTS) and \
                    any(c == TRACED for c in arg_classes):
                self._emit("TRC001", node,
                           f"`{d}()` applied to a traced value — this "
                           f"raises at trace time under jit; use jnp "
                           f"ops or hoist to build time")
            root = d.split(".", 1)[0]
            name = d.rsplit(".", 1)[-1]
            if root in ("np", "numpy") and name in _NP_CTORS:
                has_dtype = any(kw.arg == "dtype" for kw in node.keywords)
                # positional dtype slot: array/asarray/zeros/... take it
                # second, full takes it third
                pos_ok = len(node.args) >= (3 if name == "full" else 2) \
                    and name not in ("arange", "linspace")
                if not has_dtype and not pos_ok:
                    self._emit("TRC004", node,
                               f"`{d}(...)` without dtype= defaults to "
                               f"float64 and silently upcasts jnp "
                               f"expressions it leaks into")
        # .at[idx].set/add(...) without explicit mode=, inside scan bodies
        f = node.func
        if (self.in_scan and isinstance(f, ast.Attribute)
                and f.attr in _SCATTER_METHODS
                and isinstance(f.value, ast.Subscript)
                and isinstance(f.value.value, ast.Attribute)
                and f.value.value.attr == "at"):
            if not any(kw.arg == "mode" for kw in node.keywords):
                if self.expr(f.value.slice) == TRACED:
                    self._emit("TRC003", node,
                               f"`.at[...].{f.attr}(...)` with a traced "
                               f"index but no explicit mode= in a scan "
                               f"body — default FILL_OR_DROP hides OOB "
                               f"bugs; state intent with mode=")

    def on_branch(self, node: ast.AST, test_class: int) -> None:
        if test_class == TRACED:
            kind = "while" if isinstance(node, ast.While) else "if"
            self._emit("TRC002", node,
                       f"Python `{kind}` on a traced value fails under "
                       f"jit — use jnp.where / lax.cond / lax.while_loop")


def check_tracing(ctx: CheckContext) -> List[Finding]:
    index: RepoIndex = ctx.index
    seeds, scan_roots = index.seeds_and_scan_roots(NAMED_SEEDS)
    reach = index.reachable(seeds)
    scan_reach = index.reachable({k for k in scan_roots if k in index.funcs})

    findings: List[Finding] = []
    envs: Dict[str, Dict[str, int]] = {}
    # parents before nested so closures inherit the parent environment
    for key in sorted(reach, key=lambda k: (index.funcs[k].path,
                                            index.funcs[k].qual.count("."),
                                            index.funcs[k].qual)):
        fi = index.funcs[key]
        mod = index.modules[fi.path]
        init: Dict[str, int] = {}
        if fi.parent is not None:
            init = envs.get(f"{fi.path}::{fi.parent}", {})
        flow = _TracingFlow(mod, fi, init, in_scan=key in scan_reach,
                            findings=findings)
        envs[key] = flow.run()

    seen: Set[Tuple[str, str, int]] = set()
    out: List[Finding] = []
    for f in findings:
        k = (f.code, f.path, f.line)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
