"""CLI: ``python -m repro.analysis [--format=text|json|github] ...``.

Exit status is 0 when clean, 1 when any finding survives exemptions —
suitable for CI gating. ``--write-manifest`` regenerates the
wire-format freeze and exits 0.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import Optional, Sequence, Set

from repro.analysis.findings import render
from repro.analysis.runner import CHECKS, run_checks
from repro.analysis.wire import write_manifest


def _default_root() -> str:
    # .../<root>/src/repro/analysis/__main__.py -> <root>
    here = os.path.abspath(os.path.dirname(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _changed_files(root: str) -> Optional[Set[str]]:
    """Repo-relative .py files that differ from HEAD (worktree + staged
    + untracked). None when git is unavailable — caller falls back to a
    full run rather than silently passing."""
    rels: Set[str] = set()
    for cmd in (["git", "diff", "--name-only", "HEAD"],
                ["git", "diff", "--name-only", "--cached"],
                ["git", "ls-files", "--others", "--exclude-standard"]):
        try:
            p = subprocess.run(cmd, cwd=root, capture_output=True,
                               text=True)
        except OSError:
            return None
        if p.returncode != 0:
            return None
        rels.update(ln.strip() for ln in p.stdout.splitlines()
                    if ln.strip())
    return {r for r in rels if r.endswith(".py")}


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="reprolint: repo-specific JAX tracing-hazard and "
                    "wire-format contract checks")
    ap.add_argument("--root", default=_default_root(),
                    help="repo root to analyze (default: this checkout)")
    ap.add_argument("--format", dest="fmt", default="text",
                    choices=("text", "json", "github"),
                    help="report format (github emits workflow-command "
                         "annotations)")
    ap.add_argument("--checks", default=None,
                    help=f"comma-separated subset of "
                         f"{','.join(sorted(CHECKS))}")
    ap.add_argument("--manifest", default=None,
                    help="override the wire-format manifest path")
    ap.add_argument("--write-manifest", action="store_true",
                    help="regenerate the wire-format manifest and exit")
    ap.add_argument("--changed", action="store_true",
                    help="report only findings in files that differ from "
                         "git HEAD (worktree, staged, untracked) — the "
                         "analysis still runs over the whole repo so "
                         "repo-level checks stay sound")
    args = ap.parse_args(argv)

    if args.write_manifest:
        path = write_manifest(args.root, args.manifest)
        print(f"reprolint: wrote {path}")
        return 0

    checks = args.checks.split(",") if args.checks else None
    report = run_checks(args.root, checks=checks, manifest=args.manifest)
    if args.changed:
        changed = _changed_files(args.root)
        if changed is not None:
            # keep repo-level findings (path "" — e.g. a missing ring
            # guard) regardless: they have no single owning file
            report.findings = [f for f in report.findings
                               if not f.path or f.path in changed]
    out = render(report.findings, report.suppressed, report.num_files,
                 style=args.fmt)
    if out:
        print(out)
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
