"""Finding model, the frozen code catalog, exemptions, and output formats.

A finding is ``(code, path, line, message)``. Codes are wire format for
CI annotations and the fixture corpus — new checks append fresh codes,
existing codes never change meaning.

Exemptions are per-line source comments::

    x = float(t0)  # reprolint: ignore[TRC001] t0 is a build-time scalar

The comment may sit on the flagged line or the line directly above it
(for flagged expressions that span multiple lines, anchor the comment on
the reported line). Several codes may share one comment:
``ignore[TRC001,TRC004]``. A justification after the bracket is
encouraged and ignored by the parser.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, FrozenSet, Iterable, List, Sequence, Set, Tuple

# code -> one-line description (frozen; append-only)
CODES: Dict[str, str] = {
    "TRC001": "tracer cast: float()/int()/bool()/np.asarray() on a traced "
              "value inside jit-reachable code",
    "TRC002": "Python `if`/`while` on a traced value inside jit-reachable "
              "code (use jnp.where / lax.cond)",
    "TRC003": ".at[...] scatter with a traced index but no explicit mode= "
              "inside a scan body",
    "TRC004": "dtype-less np.* array constructor (float64 default) inside "
              "jit-reachable code",
    "AXS001": "ExpSpec sweep-axis classification missing or inconsistent "
              "(AXES_STATIC / AXES_DYNAMIC / AXES_EXEMPT)",
    "AXS002": "axis declared dynamic but read by spec_to_cfg — it would "
              "recompile every sweep cell",
    "AXS003": "axis declared static but never reaches the trace key via "
              "spec_to_cfg",
    "WIR001": "wire-format drift vs manifest.json — regenerate with "
              "`python -m repro.analysis --write-manifest` in this diff",
    "WIR002": "wire-format manifest missing — generate it with "
              "`python -m repro.analysis --write-manifest`",
    "RNG001": "history-ring subscript without a `% HIST` wrap (ring reads "
              "alias silently once an offset outgrows the ring)",
    "RNG002": "HIST build-time capacity guard not found (build() must "
              "validate max RTT / signal-delay offsets against HIST)",
    "UNI001": "arithmetic/comparison mixes incompatible dimensions "
              "(e.g. bytes with us) per the *_us/*_bytes/... naming "
              "convention",
    "UNI002": "same dimension, different scale: unconverted us/ms mixing "
              "(divide or multiply by the conversion factor first)",
    "UNI003": "compound unit mismatch: a derived quantity (rate x time, "
              "bytes/us) meets a plain unit without conversion",
    "UNI004": "assignment target's unit suffix contradicts the unit of "
              "the assigned expression",
    "INV001": "SimState/PacketState field mutated in the scan without a "
              "registered runtime invariant or exemption in "
              "repro.netsim.sanitize",
    "INV002": "sanitizer registry rot: coverage/exemption key is not a "
              "state field, or names an unknown invariant",
}

_IGNORE_RE = re.compile(r"#\s*reprolint:\s*ignore\[([A-Z0-9,\s]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    path: str          # repo-relative, forward slashes
    line: int          # 1-indexed; 0 = whole-file / repo-level finding
    message: str

    def format(self, style: str = "text") -> str:
        if style == "github":
            # GitHub Actions workflow-command annotation
            return (f"::error file={self.path},line={max(self.line, 1)},"
                    f"title=reprolint {self.code}::{self.message}")
        return f"{self.path}:{self.line}: {self.code} {self.message}"


def ignored_codes(source_lines: Sequence[str], line: int) -> FrozenSet[str]:
    """Codes exempted at ``line`` (1-indexed): an ``ignore[...]`` comment
    on the line itself or on the line directly above."""
    out: Set[str] = set()
    for ln in (line, line - 1):
        if 1 <= ln <= len(source_lines):
            m = _IGNORE_RE.search(source_lines[ln - 1])
            if m:
                out.update(c.strip() for c in m.group(1).split(","))
    return frozenset(out)


def apply_exemptions(
        findings: Iterable[Finding], sources: Dict[str, List[str]],
) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (kept, suppressed) using per-line comments.
    ``sources`` maps repo-relative path -> source lines."""
    kept: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        lines = sources.get(f.path, [])
        if f.line > 0 and f.code in ignored_codes(lines, f.line):
            suppressed.append(f)
        else:
            kept.append(f)
    return kept, suppressed


def render(findings: Sequence[Finding], suppressed: Sequence[Finding],
           num_files: int, style: str = "text") -> str:
    """Render a report in one of the three output formats."""
    if style == "json":
        return json.dumps({
            "findings": [dataclasses.asdict(f) for f in findings],
            "suppressed": len(suppressed),
            "files": num_files,
            "ok": not findings,
        }, indent=2, sort_keys=True)
    lines = [f.format(style) for f in findings]
    if style == "text":
        verdict = "clean" if not findings else f"{len(findings)} finding(s)"
        lines.append(f"reprolint: {verdict} over {num_files} file(s)"
                     f" ({len(suppressed)} suppressed)")
    elif not findings:
        lines.append(f"reprolint: clean over {num_files} file(s)")
    return "\n".join(lines)
