"""Checker registry, file discovery, and the single-shot ``run_checks``.

Default file set: every ``.py`` under ``<root>/src`` and
``<root>/tests``, excluding anything under a ``fixtures`` directory (the
known-bad corpus must not dirty the repo run). A root with neither
directory — a fixture tree — is walked whole instead.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Dict, List, Optional, Sequence

from repro.analysis.astutil import CheckContext, RepoIndex
from repro.analysis.axes import check_axes
from repro.analysis.findings import Finding, apply_exemptions
from repro.analysis.invariants import check_invariants
from repro.analysis.rings import check_rings
from repro.analysis.tracing import check_tracing
from repro.analysis.units import check_units
from repro.analysis.wire import check_wire

CHECKS: Dict[str, Callable[[CheckContext], List[Finding]]] = {
    "tracing": check_tracing,
    "axes": check_axes,
    "wire": check_wire,
    "rings": check_rings,
    "units": check_units,
    "invariants": check_invariants,
}


@dataclasses.dataclass
class Report:
    findings: List[Finding]
    suppressed: List[Finding]
    num_files: int

    @property
    def ok(self) -> bool:
        return not self.findings


def default_files(root: str) -> List[str]:
    roots = [d for d in (os.path.join(root, "src"),
                         os.path.join(root, "tests")) if os.path.isdir(d)]
    if not roots:
        roots = [root]
    out: List[str] = []
    for top in roots:
        for dirpath, dirnames, filenames in os.walk(top):
            dirnames[:] = [d for d in dirnames
                           if d not in ("fixtures", "__pycache__",
                                        ".git", ".ruff_cache",
                                        ".mypy_cache")]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    out.append(os.path.join(dirpath, fn))
    return sorted(out)


def run_checks(root: str, checks: Optional[Sequence[str]] = None,
               files: Optional[Sequence[str]] = None,
               manifest: Optional[str] = None) -> Report:
    root = os.path.abspath(root)
    if files is None:
        files = default_files(root)
    index = RepoIndex(root, files)
    ctx = CheckContext(root=root, index=index, manifest_path=manifest)

    names = list(checks) if checks else list(CHECKS)
    unknown = [n for n in names if n not in CHECKS]
    if unknown:
        raise ValueError(f"unknown check(s): {unknown}; "
                         f"available: {sorted(CHECKS)}")

    findings: List[Finding] = []
    for name in names:
        findings.extend(CHECKS[name](ctx))

    sources = {mod.path: mod.lines for mod in index.modules.values()}
    kept, suppressed = apply_exemptions(findings, sources)
    kept.sort(key=lambda f: (f.path, f.line, f.code))
    return Report(findings=kept, suppressed=suppressed,
                  num_files=len(index.modules))
