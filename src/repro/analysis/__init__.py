"""``reprolint`` — repo-specific static analysis for the LCMP reproduction.

Every invariant this package checks was, at some point, enforced by hand
and broken anyway (see CHANGES.md): the ``_route_arrivals`` flow-0
scatter clobber was a missing ``mode="drop"``; a new ``ExpSpec`` axis
can silently become a per-cell recompile; ``POLICY_CODES`` and the
benchmark CSV schemas are wire formats that keep figure CSVs comparable
across PRs; and a history-ring read without a ``% HIST`` wrap aliases
silently once an offset outgrows the ring. ``reprolint`` machine-checks
them on every commit:

- ``tracing``  (TRC001-TRC004): tracer casts, Python control flow on
  traced values, ``.at[...]`` scatters without an explicit ``mode=``,
  and dtype-less ``np.*`` constructors — inside *jit-reachable* code,
  with reachability seeded from the engine step functions and any
  function syntactically handed to ``jax.jit``/``lax.scan``/``vmap``.
- ``axes``     (AXS001-AXS003): every ``ExpSpec`` field must be declared
  static (trace-key member) or dynamic (per-cell array contents) in the
  ``AXES_*`` tables next to the dataclass, and the declaration must
  match how ``spec_to_cfg`` actually consumes the field.
- ``wire``     (WIR001-WIR002): a generated ``manifest.json`` freezes
  ``POLICY_CODES``, ``scenarios.names()``, ``sched.FAMILIES``, the
  benchmark CSV column schemas and the ``BENCH_netsim.json`` key set;
  any drift fails until the manifest is regenerated in the same diff.
- ``rings``    (RNG001-RNG002): every subscript into the
  ``hist_c``/``hist_q``/``hist_u``/``hist_pause`` rings must wrap with
  ``% HIST``, and the build-time ring-capacity guard must stay present.

Run ``python -m repro.analysis`` (``--format=text|json|github``); see
``docs/static_analysis.md`` for the checker catalog, the
``# reprolint: ignore[CODE]`` exemption syntax, and how to regenerate
the manifest (``python -m repro.analysis --write-manifest``).
"""
from __future__ import annotations

from repro.analysis.findings import CODES, Finding
from repro.analysis.runner import CHECKS, run_checks

__all__ = ["CODES", "CHECKS", "Finding", "run_checks"]
