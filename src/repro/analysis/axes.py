"""AXS001-AXS003: the ExpSpec sweep-axis contract.

The sweep engine compiles once per *static key* and runs every cell that
shares it; a field routed the wrong way either recompiles per cell
(static data in a dynamic axis is fine — dynamic data in the trace key
is not) or silently bakes one cell's value into every other cell.

The contract is declared next to the dataclass::

    AXES_STATIC  = ("cc", "engine", ...)   # members of the trace key
    AXES_DYNAMIC = ("load", "seed", ...)   # padded per-cell arrays
    AXES_EXEMPT  = {"topology": "why"}     # neither, with justification

and cross-checked against how ``spec_to_cfg`` actually consumes fields:

- AXS001: a field missing from all three tables, listed twice, or a
  table entry that is not a field at all.
- AXS002: declared dynamic but read by ``spec_to_cfg`` — its value
  would enter the trace key and recompile every sweep cell.
- AXS003: declared static but never read by ``spec_to_cfg`` — it never
  reaches the trace key, so cells differing only in it would share one
  compiled (and wrong) configuration.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import CheckContext, ModuleInfo, RepoIndex
from repro.analysis.findings import Finding

SPEC_CLASS = "ExpSpec"
CFG_FUNC = "spec_to_cfg"


def _str_elts(node: ast.AST) -> Optional[List[str]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                out.append(e.value)
            else:
                return None
        return out
    return None


def _extract(mod: ModuleInfo) -> Optional[Tuple[
        ast.ClassDef, List[str], Dict[str, Tuple[int, List[str]]],
        Set[str], bool]]:
    """(class node, field names, tables, spec_to_cfg reads) or None."""
    cls = None
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef) and node.name == SPEC_CLASS:
            cls = node
            break
    if cls is None:
        return None

    fields: List[str] = []
    for stmt in cls.body:
        if isinstance(stmt, ast.AnnAssign) and isinstance(stmt.target,
                                                          ast.Name):
            fields.append(stmt.target.id)

    tables: Dict[str, Tuple[int, object]] = {}
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if name in ("AXES_STATIC", "AXES_DYNAMIC"):
                elts = _str_elts(node.value)
                if elts is not None:
                    tables[name] = (node.lineno, elts)
            elif name == "AXES_EXEMPT" and isinstance(node.value, ast.Dict):
                keys = []
                for k in node.value.keys:
                    if isinstance(k, ast.Constant) and isinstance(k.value,
                                                                  str):
                        keys.append(k.value)
                tables[name] = (node.lineno, keys)

    reads: Set[str] = set()
    cfg_fn = mod.funcs.get(CFG_FUNC)
    if cfg_fn is not None and isinstance(cfg_fn.node, ast.FunctionDef):
        fn = cfg_fn.node
        if fn.args.args:
            spec_name = fn.args.args[0].arg
            for n in ast.walk(fn):
                if isinstance(n, ast.Attribute) and \
                        isinstance(n.value, ast.Name) and \
                        n.value.id == spec_name:
                    reads.add(n.attr)
    return cls, fields, tables, reads, cfg_fn is not None


def check_axes(ctx: CheckContext) -> List[Finding]:
    index: RepoIndex = ctx.index
    findings: List[Finding] = []
    for mod in index.modules.values():
        got = _extract(mod)
        if got is None:
            continue
        cls, fields, tables, reads, has_cfg = got

        missing_tables = [t for t in ("AXES_STATIC", "AXES_DYNAMIC",
                                      "AXES_EXEMPT") if t not in tables]
        if missing_tables:
            findings.append(Finding(
                code="AXS001", path=mod.path, line=cls.lineno,
                message=f"{SPEC_CLASS} has no "
                        f"{'/'.join(missing_tables)} table(s) — every "
                        f"sweep axis must be declared static, dynamic, "
                        f"or exempt-with-justification"))
            continue

        line_static, static = tables["AXES_STATIC"]
        line_dynamic, dynamic = tables["AXES_DYNAMIC"]
        line_exempt, exempt = tables["AXES_EXEMPT"]
        declared = list(static) + list(dynamic) + list(exempt)

        for field in fields:
            n = declared.count(field)
            if n == 0:
                findings.append(Finding(
                    code="AXS001", path=mod.path, line=cls.lineno,
                    message=f"field `{field}` is in no AXES_* table — "
                            f"classify it static, dynamic, or exempt"))
            elif n > 1:
                findings.append(Finding(
                    code="AXS001", path=mod.path, line=line_static,
                    message=f"field `{field}` appears in more than one "
                            f"AXES_* table"))
        for name in declared:
            if name not in fields:
                findings.append(Finding(
                    code="AXS001", path=mod.path, line=line_static,
                    message=f"AXES_* entry `{name}` is not an "
                            f"{SPEC_CLASS} field"))

        if has_cfg:
            for field in dynamic:
                if field in reads and field not in exempt:
                    findings.append(Finding(
                        code="AXS002", path=mod.path, line=line_dynamic,
                        message=f"axis `{field}` is declared dynamic "
                                f"but read by {CFG_FUNC} — its value "
                                f"enters the trace key and recompiles "
                                f"every sweep cell"))
            for field in static:
                if field not in reads and field not in exempt:
                    findings.append(Finding(
                        code="AXS003", path=mod.path, line=line_static,
                        message=f"axis `{field}` is declared static but "
                                f"{CFG_FUNC} never reads it — it cannot "
                                f"reach the trace key, so cells "
                                f"differing only in it share one "
                                f"compiled config"))
    return findings
