"""UNI001-UNI004: conservative dimension-flow analysis over the naming
conventions the codebase already follows everywhere (``*_us``, ``*_ms``,
``*_bytes``, ``*_gbps``, ``*_km``).

A unit is a reduced fraction over the base tokens — ``us``,
``bytes/us``, ``gbps*us`` — seeded from name/attribute suffixes and
propagated through assignments, arithmetic, and a whitelist of
unit-preserving calls. The analysis only flags *provable* mismatches:

- multiplying or dividing by a bare numeric literal erases the unit
  (it is how conversions are written — ``y_us / 1000`` is the µs→ms
  idiom, ``cap_gbps * 125.0`` the Gbps→bytes/µs one), so a converted
  value never false-positives;
- unknown values (unsuffixed names, unresolved calls) are compatible
  with everything;
- dimensionless ratios (``us/us``) are compatible with everything.

What still fires is the real bug class: ``delay_us + gap_ms`` (UNI002),
``q_bytes > horizon_us`` (UNI001), ``q_bytes + rate_gbps * dt_us``
without the 125 conversion (UNI003), ``delay_us = dist_km`` (UNI004).
``UNITS_OVERRIDES`` corrects names whose spelling lies about (or hides)
their unit.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.astutil import (
    CheckContext, FuncInfo, ModuleInfo, RepoIndex, ValueFlow,
)
from repro.analysis.findings import Finding

# base dimension tokens recognized as name suffixes ("x_us", "size_bytes")
BASE_TOKENS = ("us", "ms", "bytes", "gbps", "km")
# token -> physical dimension (us and ms share one: mixing them is a
# *scale* bug — UNI002 — not a dimension bug)
_DIM = {"us": "time", "ms": "time", "bytes": "data", "gbps": "rate",
        "km": "length"}

# name -> unit token (or None to silence inference for that name).
# The escape hatch for spellings the suffix convention gets wrong.
UNITS_OVERRIDES: Dict[str, Optional[str]] = {
    "path_prop": "us",        # engine.SimArrays: per-path propagation, µs
    "arrival_us": "us",
    "prop": "us",
    # workload CDF tables: "kb"-named but stored in bytes post-parse
    "mean_kb": None,
}

# A unit is a reduced fraction (numerator tokens, denominator tokens),
# both sorted. DIMLESS is the empty fraction; ANY marks bare literals
# (compatible with everything in additive/compare positions); None means
# "no information".
Unit = Tuple[Tuple[str, ...], Tuple[str, ...]]
DIMLESS: Unit = ((), ())
ANY = "any"

# calls that return their first argument's unit unchanged
_PASS_FUNCS = {"float", "int", "abs", "round", "asarray", "array", "sum",
               "mean", "median", "cumsum", "floor", "ceil", "sort",
               "sqrt_preserving", "squeeze", "ravel", "reshape", "take",
               "amax", "amin", "max", "min", "nanmax", "nanmin",
               "percentile", "quantile", "block_until_ready"}
# receiver-preserving method calls (x.astype(...), fq.sum(-1), ...)
_PASS_METHODS = {"astype", "sum", "mean", "max", "min", "clip", "reshape",
                 "squeeze", "ravel", "flatten", "cumsum", "take", "sort",
                 "copy", "any", "all", "item"}
# joins: every data argument must be unit-compatible; result is the merge
_JOIN_FUNCS = {"maximum", "minimum", "fmax", "fmin", "hypot"}


def name_unit(name: str) -> Optional[Unit]:
    """Unit a bare name or attribute spelling declares, if any."""
    if name in UNITS_OVERRIDES:
        tok = UNITS_OVERRIDES[name]
        return ((tok,), ()) if tok else None
    tail = name.rsplit("_", 1)[-1]
    if tail in BASE_TOKENS:
        return ((tail,), ())
    return None


def _mul(a: Unit, b: Unit) -> Unit:
    num = list(a[0]) + list(b[0])
    den = list(a[1]) + list(b[1])
    for tok in list(num):          # cancel us/us etc.
        if tok in den:
            num.remove(tok)
            den.remove(tok)
    return (tuple(sorted(num)), tuple(sorted(den)))


def _inv(a: Unit) -> Unit:
    return (a[1], a[0])


def _is_compound(u: Unit) -> bool:
    return len(u[0]) + len(u[1]) != 1 or bool(u[1])


def _fmt(u: Unit) -> str:
    if u == DIMLESS:
        return "dimensionless"
    num = "*".join(u[0]) or "1"
    return f"{num}/{'*'.join(u[1])}" if u[1] else num


def _mismatch_code(a: Unit, b: Unit) -> str:
    if _is_compound(a) or _is_compound(b):
        return "UNI003"
    return "UNI002" if _DIM[a[0][0]] == _DIM[b[0][0]] else "UNI001"


class _UnitFlow(ValueFlow):
    """Statement walker with a parallel name -> Unit environment.

    Reuses ValueFlow's statement dispatch (and two-pass loop settling);
    the unit evaluation happens in pre-hooks so every expression a
    statement evaluates is also unit-checked.
    """

    def __init__(self, mod: ModuleInfo, fi: FuncInfo,
                 init_env: Optional[Dict[str, int]],
                 init_units: Optional[Dict[str, object]],
                 findings: List[Finding]) -> None:
        super().__init__(mod, fi, init_env)
        self.units: Dict[str, object] = dict(init_units or {})
        self.findings = findings
        # seed parameter units from their names (def f(dt_us, size_bytes))
        node = fi.node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            args = node.args
            for a in (list(args.posonlyargs) + list(args.args)
                      + list(args.kwonlyargs)):
                u = name_unit(a.arg)
                if u is not None:
                    self.units[a.arg] = u

    def _emit(self, code: str, node: ast.AST, msg: str) -> None:
        self.findings.append(Finding(
            code=code, path=self.mod.path,
            line=getattr(node, "lineno", 0),
            message=f"{msg} [in `{self.fi.qual}`]"))

    # ------------------------------------------------- statement pre-hooks
    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.Assign):
            u = self.unit(stmt.value)
            for tgt in stmt.targets:
                self._bind_unit(tgt, u, stmt)
        elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
            self._bind_unit(stmt.target, self.unit(stmt.value), stmt)
        elif isinstance(stmt, ast.AugAssign):
            if isinstance(stmt.target, ast.Name):
                tu = self._name_lookup(stmt.target.id)
                r = self._binop_unit(stmt.op, tu, self.unit(stmt.value),
                                     stmt)
                if r is not ANY:
                    self.units[stmt.target.id] = r
            else:
                self.unit(stmt.value)
        elif isinstance(stmt, (ast.If, ast.While)):
            self.unit(stmt.test)
        elif isinstance(stmt, ast.Assert):
            self.unit(stmt.test)
        elif isinstance(stmt, (ast.Return, ast.Expr)) and \
                stmt.value is not None:
            self.unit(stmt.value)
        super()._stmt(stmt)

    def _bind_unit(self, target: ast.expr, u: object,
                   stmt: ast.stmt) -> None:
        if isinstance(target, ast.Name):
            declared = name_unit(target.id)
            if declared is not None:
                if (isinstance(u, tuple) and u not in (DIMLESS, declared)):
                    self._emit(
                        "UNI004", stmt,
                        f"`{target.id}` declares unit {_fmt(declared)} by "
                        f"its suffix but is assigned a value of unit "
                        f"{_fmt(u)}")
                self.units[target.id] = declared   # trust the declaration
            elif isinstance(u, tuple):
                self.units[target.id] = u
            else:
                self.units.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind_unit(elt, None, stmt)

    def _name_lookup(self, name: str) -> object:
        if name in self.units:
            return self.units[name]
        return name_unit(name)

    # ------------------------------------------------------ unit evaluator
    def _check(self, a: object, b: object, node: ast.AST,
               what: str) -> None:
        if not (isinstance(a, tuple) and isinstance(b, tuple)):
            return
        if a == b or DIMLESS in (a, b):
            return
        self._emit(_mismatch_code(a, b), node,
                   f"{what} mixes {_fmt(a)} with {_fmt(b)}")

    def _merge(self, a: object, b: object) -> object:
        if a is ANY:
            return b
        if b is ANY:
            return a
        if isinstance(a, tuple) and isinstance(b, tuple) and a == b:
            return a
        return None

    def _binop_unit(self, op: ast.operator, lu: object, ru: object,
                    node: ast.AST) -> object:
        if isinstance(op, (ast.Add, ast.Sub)):
            self._check(lu, ru, node,
                        "`-`" if isinstance(op, ast.Sub) else "`+`")
            return self._merge(lu, ru)
        if isinstance(op, ast.Mult):
            if lu is ANY or ru is ANY:
                return None        # literal factor = conversion license
            if isinstance(lu, tuple) and isinstance(ru, tuple):
                return _mul(lu, ru)
            return None
        if isinstance(op, (ast.Div, ast.FloorDiv)):
            if lu is ANY or ru is ANY:
                return None
            if isinstance(lu, tuple) and isinstance(ru, tuple):
                return _mul(lu, _inv(ru))
            return None
        if isinstance(op, ast.Mod):
            return lu if isinstance(lu, tuple) else None
        return None

    def unit(self, node: ast.expr) -> object:
        if isinstance(node, ast.Constant):
            return ANY
        if isinstance(node, ast.Name):
            return self._name_lookup(node.id)
        if isinstance(node, ast.Attribute):
            return name_unit(node.attr)
        if isinstance(node, ast.Subscript):
            self.unit(node.slice)
            return self.unit(node.value)
        if isinstance(node, ast.BinOp):
            return self._binop_unit(node.op, self.unit(node.left),
                                    self.unit(node.right), node)
        if isinstance(node, ast.UnaryOp):
            return self.unit(node.operand)
        if isinstance(node, ast.Compare):
            lu = self.unit(node.left)
            for cmp_ in node.comparators:
                self._check(lu, self.unit(cmp_), node, "comparison")
            return None
        if isinstance(node, ast.IfExp):
            self.unit(node.test)
            bu, ou = self.unit(node.body), self.unit(node.orelse)
            self._check(bu, ou, node, "conditional branches")
            return self._merge(bu, ou)
        if isinstance(node, ast.Call):
            return self._call_unit(node)
        if isinstance(node, ast.BoolOp):
            for v in node.values:
                self.unit(v)
            return None
        if isinstance(node, ast.NamedExpr):
            u = self.unit(node.value)
            self._bind_unit(node.target, u, node)
            return u
        # generic: evaluate child expressions (to surface nested
        # comparisons/binops), contribute no unit
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                self.unit(child)
        return None

    def _call_unit(self, node: ast.Call) -> object:
        f = node.func
        arg_units = [self.unit(a) for a in node.args]
        for kw in node.keywords:
            self.unit(kw.value)
        last = None
        if isinstance(f, ast.Name):
            last = f.id
        elif isinstance(f, ast.Attribute):
            last = f.attr
        if last in _JOIN_FUNCS and len(arg_units) >= 2:
            self._check(arg_units[0], arg_units[1], node, f"`{last}`")
            return self._merge(arg_units[0], arg_units[1])
        if last == "where" and len(arg_units) == 3:
            self._check(arg_units[1], arg_units[2], node, "`where` arms")
            return self._merge(arg_units[1], arg_units[2])
        if last == "clip" and arg_units:
            for bound in arg_units[1:3]:
                self._check(arg_units[0], bound, node, "`clip` bound")
            return arg_units[0]
        if last in _PASS_FUNCS and isinstance(f, (ast.Name, ast.Attribute)):
            if arg_units:
                return arg_units[0]
            # method form: unit of the receiver
            if isinstance(f, ast.Attribute):
                return self.unit(f.value)
            return None
        if isinstance(f, ast.Attribute) and last in _PASS_METHODS:
            return self.unit(f.value)
        # `.at[...].set(v)` / `.add(v)`: unit of the underlying array
        if isinstance(f, ast.Attribute) and isinstance(f.value,
                                                       ast.Subscript):
            base = f.value.value
            if isinstance(base, ast.Attribute) and base.attr == "at":
                return self.unit(base.value)
        # a helper spelled with a unit suffix declares its return unit
        if last is not None:
            u = name_unit(last)
            if u is not None:
                return u
        return None


def check_units(ctx: CheckContext) -> List[Finding]:
    """Run the unit flow over every function in the index (skipping test
    code, where synthetic constants mix freely)."""
    index: RepoIndex = ctx.index
    findings: List[Finding] = []
    unit_envs: Dict[str, Dict[str, object]] = {}
    lattice_envs: Dict[str, Dict[str, int]] = {}
    keys = [k for k, fi in index.funcs.items()
            if not fi.path.startswith("tests/")
            and isinstance(fi.node, (ast.FunctionDef, ast.AsyncFunctionDef))]
    # parents before nested so closures inherit both environments
    for key in sorted(keys, key=lambda k: (index.funcs[k].path,
                                           index.funcs[k].qual.count("."),
                                           index.funcs[k].qual)):
        fi = index.funcs[key]
        mod = index.modules[fi.path]
        init_l: Dict[str, int] = {}
        init_u: Dict[str, object] = {}
        if fi.parent is not None:
            init_l = lattice_envs.get(f"{fi.path}::{fi.parent}", {})
            init_u = unit_envs.get(f"{fi.path}::{fi.parent}", {})
        flow = _UnitFlow(mod, fi, init_l, init_u, findings)
        lattice_envs[key] = flow.run()
        unit_envs[key] = flow.units

    seen: Set[Tuple[str, str, int]] = set()
    out: List[Finding] = []
    for f in findings:
        k = (f.code, f.path, f.line)
        if k not in seen:
            seen.add(k)
            out.append(f)
    return out
