"""WIR001/WIR002: wire-format freeze against a generated manifest.

The manifest (``src/repro/analysis/manifest.json``) snapshots every
cross-PR comparison surface:

- ``policy_codes``  — ``engine.POLICY_CODES`` (figure CSVs and sweep
  cells encode policies by these integers)
- ``scenario_names`` — ``scenarios.names()`` registry
- ``sched_families`` — ``traffic.sched.FAMILIES``
- ``csv_schemas``   — column header of every ``_csv(...)`` emit site in
  ``benchmarks/figures.py`` (extracted from the AST, so the freeze
  tracks the code, not a stale doc)
- ``bench_keys``    — the ``meta`` / ``rows_us`` key sets of
  ``BENCH_netsim.json``
- ``checker_codes`` — the reprolint finding-code catalog itself (codes
  appear in CI annotations and exemption comments, so they are
  advertised surface too)

Any drift fails CI until the manifest is regenerated **in the same
diff** (``python -m repro.analysis --write-manifest``), which turns a
silent wire-format change into an explicit, reviewable file change.
"""
from __future__ import annotations

import ast
import json
import os
import sys
from typing import Any, Dict, List, Optional, Tuple

from repro.analysis.astutil import CheckContext
from repro.analysis.findings import CODES, Finding

MANIFEST_REL = "src/repro/analysis/manifest.json"
REGEN = "python -m repro.analysis --write-manifest"


def _import_repro(root: str) -> Tuple[Any, Any, Any]:
    src = os.path.join(root, "src")
    if os.path.isdir(src) and src not in sys.path:
        sys.path.insert(0, src)
    from repro.netsim import engine, scenarios  # noqa: PLC0415
    from repro.traffic import sched  # noqa: PLC0415
    return engine, scenarios, sched


def _csv_schemas(figures_path: str) -> Dict[str, List[str]]:
    """{csv filename: [columns]} from every ``_csv(...)`` call site."""
    with open(figures_path, encoding="utf-8") as f:
        tree = ast.parse(f.read(), filename=figures_path)
    out: Dict[str, List[str]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "_csv"
                and len(node.args) >= 2):
            continue
        name_arg, header_arg = node.args[0], node.args[1]
        name: Optional[str] = None
        if isinstance(name_arg, ast.Constant) and isinstance(name_arg.value,
                                                             str):
            name = name_arg.value
        elif (isinstance(name_arg, ast.Call) and name_arg.args
              and isinstance(name_arg.args[0], ast.Constant)
              and isinstance(name_arg.args[0].value, str)):
            name = name_arg.args[0].value
        if name is None:
            continue
        if isinstance(header_arg, ast.Constant) and \
                isinstance(header_arg.value, str):
            out[name] = header_arg.value.split(",")
    return out


def build_manifest(root: str) -> Dict:
    engine, scenarios, sched = _import_repro(root)
    bench_path = os.path.join(root, "BENCH_netsim.json")
    bench: Dict[str, List[str]] = {}
    if os.path.exists(bench_path):
        with open(bench_path, encoding="utf-8") as f:
            data = json.load(f)
        bench = {"top": sorted(data),
                 "meta": sorted(data.get("meta", {})),
                 "rows_us": sorted(data.get("rows_us", {}))}
    return {
        "format": 1,
        "policy_codes": dict(engine.POLICY_CODES),
        "redecide_policies": list(engine.REDECIDE_POLICIES),
        "scenario_names": list(scenarios.names()),
        "sched_families": list(sched.FAMILIES),
        "csv_schemas": _csv_schemas(
            os.path.join(root, "benchmarks", "figures.py")),
        "bench_keys": bench,
        "checker_codes": sorted(CODES),
    }


def write_manifest(root: str, path: Optional[str] = None) -> str:
    path = path or os.path.join(root, MANIFEST_REL)
    manifest = build_manifest(root)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def _diff_section(name: str, want: Any, got: Any) -> str:
    if isinstance(want, dict) and isinstance(got, dict):
        added = sorted(set(got) - set(want))
        removed = sorted(set(want) - set(got))
        changed = sorted(k for k in set(want) & set(got)
                         if want[k] != got[k])
        bits = []
        if added:
            bits.append(f"added {added}")
        if removed:
            bits.append(f"removed {removed}")
        if changed:
            bits.append(f"changed {changed}")
        return "; ".join(bits) or "differs"
    if isinstance(want, list) and isinstance(got, list):
        added = sorted(set(map(str, got)) - set(map(str, want)))
        removed = sorted(set(map(str, want)) - set(map(str, got)))
        bits = []
        if added:
            bits.append(f"added {added}")
        if removed:
            bits.append(f"removed {removed}")
        return "; ".join(bits) or "reordered"
    return f"was {want!r}, now {got!r}"


def check_wire(ctx: CheckContext) -> List[Finding]:
    root = ctx.root
    # only meaningful on the real repo layout (fixture trees skip)
    if not os.path.exists(os.path.join(root, "src", "repro", "netsim",
                                       "engine.py")):
        return []
    manifest_path = ctx.manifest_path or os.path.join(root, MANIFEST_REL)
    rel = os.path.relpath(manifest_path, root).replace(os.sep, "/")
    if not os.path.exists(manifest_path):
        return [Finding(code="WIR002", path=rel, line=0,
                        message=f"wire-format manifest not found — "
                                f"generate it with `{REGEN}`")]
    with open(manifest_path, encoding="utf-8") as f:
        frozen = json.load(f)
    current = build_manifest(root)
    findings: List[Finding] = []
    for section in sorted(set(frozen) | set(current)):
        want, got = frozen.get(section), current.get(section)
        if want != got:
            findings.append(Finding(
                code="WIR001", path=rel, line=0,
                message=f"wire format drifted in `{section}`: "
                        f"{_diff_section(section, want, got)} — if "
                        f"intentional, regenerate with `{REGEN}` in "
                        f"this same diff"))
    return findings
