"""mixtral-8x7b [moe]: 8 experts top-2, SWA
[arXiv:2401.04088; hf]. 32L d_model=4096 32H (kv=8) d_ff=14336 vocab=32000."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=32000, n_experts=8, top_k=2,
    window=4096)

SMOKE = ArchConfig(
    name="mixtral-smoke", family="moe", n_layers=3, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, n_experts=4, top_k=2,
    window=32)
