"""dbrx-132b [moe]: 16 experts top-4, fine-grained
[hf:databricks/dbrx-base]. 40L d_model=6144 48H (kv=8) d_ff=10752
vocab=100352."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
    n_heads=48, n_kv=8, d_ff=10752, vocab=100352, n_experts=16, top_k=4)

SMOKE = ArchConfig(
    name="dbrx-smoke", family="moe", n_layers=3, d_model=128,
    n_heads=8, n_kv=2, d_ff=256, vocab=512, n_experts=4, top_k=2)
