"""mistral-nemo-12b [dense]: 128k ctx, head_dim 128
(d_model 5120 with 32x128 attention) [hf:mistralai/Mistral-Nemo-Base-2407]."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense", n_layers=40, d_model=5120,
    n_heads=32, n_kv=8, d_ff=14336, vocab=131072, head_dim=128,
    rope_theta=1_000_000.0)

SMOKE = ArchConfig(
    name="nemo-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=64,
    rope_theta=1_000_000.0)
