"""gemma2-9b [dense]: local+global alternating attention with
logit softcaps [arXiv:2408.00118; hf]. 42L d_model=3584 16H (GQA kv=8)
d_ff=14336 vocab=256000, head_dim=256, window 4096 on local layers."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
    n_heads=16, n_kv=8, d_ff=14336, vocab=256000, head_dim=256,
    alt_local_global=True, window=4096, attn_softcap=50.0,
    final_softcap=30.0)

SMOKE = ArchConfig(
    name="gemma2-smoke", family="dense", n_layers=4, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, head_dim=32,
    alt_local_global=True, window=32, attn_softcap=50.0,
    final_softcap=30.0)
