"""Assigned architecture registry: ``get(arch_id)`` and ``ARCHS``.

Each <id>.py module exports CONFIG (full assigned config) and
SMOKE (reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "zamba2_1p2b", "gemma2_9b", "glm4_9b", "mistral_nemo_12b", "qwen3_4b",
    "internvl2_2b", "falcon_mamba_7b", "mixtral_8x7b", "dbrx_132b",
    "whisper_medium",
]

ALIASES = {
    "zamba2-1.2b": "zamba2_1p2b", "gemma2-9b": "gemma2_9b",
    "glm4-9b": "glm4_9b", "mistral-nemo-12b": "mistral_nemo_12b",
    "qwen3-4b": "qwen3_4b", "internvl2-2b": "internvl2_2b",
    "falcon-mamba-7b": "falcon_mamba_7b", "mixtral-8x7b": "mixtral_8x7b",
    "dbrx-132b": "dbrx_132b", "whisper-medium": "whisper_medium",
}


def get(arch_id: str, smoke: bool = False):
    mod_name = ALIASES.get(arch_id, arch_id)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False):
    return {a: get(a, smoke) for a in ARCH_IDS}
