"""internvl2-2b [vlm]: InternViT frontend (STUB — precomputed
patch embeddings) + InternLM2 backbone [arXiv:2404.16821; hf].
24L d_model=2048 16H (kv=8) d_ff=8192 vocab=92553, 256 patch tokens."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm", n_layers=24, d_model=2048,
    n_heads=16, n_kv=8, d_ff=8192, vocab=92553, n_patches=256)

SMOKE = ArchConfig(
    name="internvl2-smoke", family="vlm", n_layers=3, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, n_patches=16)
