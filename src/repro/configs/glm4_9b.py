"""glm4-9b [dense]: RoPE, extreme GQA (kv=2)
[hf:THUDM/glm-4-9b; hf]. 40L d_model=4096 32H d_ff=13696 vocab=151552."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv=2, d_ff=13696, vocab=151552)

SMOKE = ArchConfig(
    name="glm4-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=8, n_kv=2, d_ff=256, vocab=512)
