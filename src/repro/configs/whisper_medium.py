"""whisper-medium [audio]: enc-dec, conv frontend STUB
(precomputed frame embeddings) [arXiv:2212.04356]. 24L enc + 24L dec,
d_model=1024 16H (kv=16) d_ff=4096 vocab=51865, enc_seq=1500."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv=16, d_ff=4096, vocab=51865, n_enc_layers=24,
    enc_seq=1500)

SMOKE = ArchConfig(
    name="whisper-smoke", family="encdec", n_layers=3, d_model=128,
    n_heads=4, n_kv=4, d_ff=256, vocab=512, n_enc_layers=2, enc_seq=32)
