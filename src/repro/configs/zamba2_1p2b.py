"""zamba2-1.2b [hybrid]: Mamba2 + shared attention blocks
[arXiv:2411.15242; hf]. 38L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=32000, ssm_state=64. The shared transformer block fires every 6
Mamba2 layers; at 500k context the shared attention runs sliding-window
(sub-quadratic) — see DESIGN.md arch table."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32000, ssm_state=64,
    shared_attn_every=6, window=4096)

SMOKE = ArchConfig(
    name="zamba2-smoke", family="hybrid", n_layers=4, d_model=128,
    n_heads=4, n_kv=4, d_ff=256, vocab=512, ssm_state=16,
    shared_attn_every=2, window=64)
