"""falcon-mamba-7b [ssm]: attention-free Mamba-1
[arXiv:2410.05355]. 64L d_model=4096 vocab=65024, ssm_state=16."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
    n_heads=1, n_kv=1, d_ff=0, vocab=65024, ssm_state=16,
    mamba_version=1)

SMOKE = ArchConfig(
    name="falcon-mamba-smoke", family="ssm", n_layers=3, d_model=128,
    n_heads=1, n_kv=1, d_ff=0, vocab=512, ssm_state=8, mamba_version=1)
