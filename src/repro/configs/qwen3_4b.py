"""qwen3-4b [dense]: qk-norm + GQA [hf:Qwen/Qwen3-8B family].
36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936."""
from repro.models.arch import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b", family="dense", n_layers=36, d_model=2560,
    n_heads=32, n_kv=8, d_ff=9728, vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1_000_000.0)

SMOKE = ArchConfig(
    name="qwen3-smoke", family="dense", n_layers=3, d_model=128,
    n_heads=4, n_kv=2, d_ff=256, vocab=512, qk_norm=True)
