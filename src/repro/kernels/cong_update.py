"""Pallas TPU kernel: fleet-wide congestion-register update (paper §3.3).

One monitor tick for *many* ports at once (a pod-level telemetry sweep
updates thousands of per-route registers): Eq. 3 shift-EWMA, qThresh /
trend-threshold quantization, duration counter, and the fused C_cong —
all int32 adds/shifts/compares on the VPU.

Layout: ports on the lane axis (blocks of 128); the threshold vectors
ride along as (16, 128) blocks (per-port trend thresholds are genuinely
per-lane; the shared qThresh/levelScore vectors are broadcast to lanes by
the wrapper — 8 KiB per block, negligible VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.cong import CongParams, CongState
from repro.core.tables import SCORE_MAX, SwitchTables

BP = 128          # ports per block
NLEV = 16         # quantization levels (matches tables default)


def _cong_kernel(qcur_ref, qprev_ref, trend_ref, dur_ref,
                 qnew_ref, qth_ref, tth_ref, lsc_ref, hw_ref,
                 o_qcur_ref, o_qprev_ref, o_trend_ref, o_dur_ref, o_cc_ref, *,
                 w_ql: int, w_tl: int, w_dp: int, ewma_k: int,
                 dur_shift: int, s_cong: int):
    q_old = qcur_ref[0, :]
    trend_old = trend_ref[0, :]
    dur_old = dur_ref[0, :]
    q = qnew_ref[0, :]

    # Eq. (3): shift-based EWMA of queue deltas
    delta = q - q_old
    trend = trend_old - (trend_old >> ewma_k) + (delta >> ewma_k)

    # quantize queue level: count thresholds <= q  (15 vector compares)
    q_level = jnp.zeros_like(q)
    t_level = jnp.zeros_like(q)
    for i in range(NLEV - 1):
        q_level += (qth_ref[i, :] <= q).astype(jnp.int32)
        t_level += (tth_ref[i, :] <= trend).astype(jnp.int32)

    hw = hw_ref[0, :]
    dur = jnp.where(q_level >= hw, dur_old + 1, dur_old >> 1)

    # level -> score via one-hot gather over the 16 levelScore rows
    q_score = jnp.zeros_like(q)
    t_score = jnp.zeros_like(q)
    for i in range(NLEV):
        s = lsc_ref[i, :]
        q_score = jnp.where(q_level == i, s, q_score)
        t_score = jnp.where(t_level == i, s, t_score)
    t_score = jnp.where(trend > 0, t_score, 0)
    d_score = jnp.minimum(dur >> dur_shift, SCORE_MAX)

    fused = w_ql * q_score + w_tl * t_score + w_dp * d_score
    c_cong = jnp.minimum(fused >> s_cong, SCORE_MAX)

    o_qcur_ref[0, :] = q
    o_qprev_ref[0, :] = q_old
    o_trend_ref[0, :] = trend
    o_dur_ref[0, :] = dur
    o_cc_ref[0, :] = c_cong


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def cong_update(state: CongState, queue_cells: jnp.ndarray, now_us,
                tables: SwitchTables, params: CongParams = CongParams(),
                interpret: bool = True):
    """Fleet monitor tick. state fields (N,); queue_cells (N,) int32 cells.
    Returns (new CongState, c_cong (N,) int32)."""
    n = state.queue_cur.shape[0]
    n_pad = (n + BP - 1) // BP * BP

    def pad1(x):
        return jnp.pad(x.astype(jnp.int32), (0, n_pad - n)).reshape(1, n_pad)

    # per-port trend thresholds -> (15, N); shared vectors broadcast to lanes
    tth = jnp.pad(tables.trend_thresh.astype(jnp.int32).T,
                  ((0, 1), (0, n_pad - n)))                     # (16, n_pad)
    qth = jnp.broadcast_to(
        jnp.pad(tables.q_thresh.astype(jnp.int32), (0, 1))[:, None],
        (NLEV, n_pad))
    lsc = jnp.broadcast_to(tables.level_score.astype(jnp.int32)[:, None],
                           (NLEV, n_pad))
    hw = jnp.broadcast_to(tables.high_water_level.astype(jnp.int32),
                          (1, n_pad))

    grid = (n_pad // BP,)
    row = pl.BlockSpec((1, BP), lambda i: (0, i), memory_space=pltpu.VMEM)
    tbl = pl.BlockSpec((NLEV, BP), lambda i: (0, i), memory_space=pltpu.VMEM)
    kern = functools.partial(
        _cong_kernel, w_ql=params.w_ql, w_tl=params.w_tl, w_dp=params.w_dp,
        ewma_k=params.ewma_k, dur_shift=params.dur_shift, s_cong=params.s_cong)
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[row, row, row, row, row, tbl, tbl, tbl, row],
        out_specs=[row] * 5,
        out_shape=[jax.ShapeDtypeStruct((1, n_pad), jnp.int32)] * 5,
        interpret=interpret,
        name="cong_update",
    )(pad1(state.queue_cur), pad1(state.queue_prev), pad1(state.trend),
      pad1(state.dur_cnt), pad1(queue_cells), qth, tth, lsc, hw)

    qcur, qprev, trend, dur, cc = [o[0, :n] for o in outs]
    new_state = CongState(
        queue_cur=qcur, queue_prev=qprev, trend=trend, dur_cnt=dur,
        last_sample=jnp.broadcast_to(jnp.asarray(now_us, jnp.int32), (n,)))
    return new_state, cc
