"""Pallas TPU kernel: batched LCMP routing decisions (paper §3.4 on VPU).

The switch-ASIC decision pipeline (fuse costs -> sort m<=8 candidates ->
drop high-cost suffix -> hash inside kept set) is re-tiled for the TPU
vector unit:

- layout: candidates on the **sublane** axis (padded to 8), flows on the
  **lane** axis (blocks of 128) — a Batcher odd-even sorting network over
  8 sublane rows is 19 vectorized compare-exchanges, each a (1,128) int32
  min/max, i.e. the MXU-free VPU analogue of the ASIC's comparator tree.
- all arithmetic is int32/uint32 (adds, shifts, selects) exactly matching
  ``repro.core.select`` bit-for-bit.
- one kernel invocation decides 128 flows; the grid walks the flow axis.

VMEM budget per block: 4 inputs x (8,128) int32 + 1 flow row + out
= ~17 KiB — far under the ~16 MiB VMEM of a TPU core; the block shape is
chosen for lane alignment, not capacity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.core.select import SelectParams

P_PAD = 8          # candidate axis, padded (paper: m in [2,8])
BF = 128           # flows per block (lane width)
_COST_INVALID = 1 << 24
_SCORE_MAX = 255

# Batcher odd-even mergesort network for n=8 (19 comparators)
_NETWORK = [(0, 1), (2, 3), (4, 5), (6, 7),
            (0, 2), (1, 3), (4, 6), (5, 7),
            (1, 2), (5, 6),
            (0, 4), (1, 5), (2, 6), (3, 7),
            (2, 4), (3, 5),
            (1, 2), (3, 4), (5, 6)]


def _fmix32(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _decide_kernel(fid_ref, cpath_ref, ccong_ref, valid_ref, out_ref, *,
                   alpha: int, beta: int, keep_num: int, cong_fallback: int):
    fids = fid_ref[0, :]                        # (BF,) uint32
    c_path = cpath_ref[...]                     # (8, BF) int32
    c_cong = ccong_ref[...]
    valid = valid_ref[...] != 0                 # (8, BF) bool

    cost = alpha * c_path + beta * c_cong
    cost = jnp.where(valid, cost, _COST_INVALID)
    row = jax.lax.broadcasted_iota(jnp.int32, (P_PAD, BF), 0)
    key = cost * P_PAD + row                    # embed index for stable argsort

    # --- stage 1: Batcher sorting network over the sublane axis ---------
    rows = [key[i, :] for i in range(P_PAD)]    # 8 vector registers
    for i, j in _NETWORK:
        lo = jnp.minimum(rows[i], rows[j])
        hi = jnp.maximum(rows[i], rows[j])
        rows[i], rows[j] = lo, hi
    sorted_key = jnp.stack(rows)                # (8, BF) ascending

    # --- stage 2: suffix filter + hash inside the kept set --------------
    num_valid = valid.astype(jnp.int32).sum(0)                  # (BF,)
    keep = jnp.maximum((num_valid + keep_num - 1) // keep_num, 1)
    h = _fmix32(fids)
    pick = (h % keep.astype(jnp.uint32)).astype(jnp.int32)      # (BF,)

    # fallback: all candidates highly congested -> argmin fused (rank 0)
    min_cong = jnp.where(valid, c_cong, _SCORE_MAX + 1).min(0)
    pick = jnp.where(min_cong >= cong_fallback, 0, pick)

    # one-hot row gather of the picked rank (8 rows, vectorized)
    picked = jnp.zeros((BF,), jnp.int32)
    for i in range(P_PAD):
        picked = jnp.where(pick == i, sorted_key[i, :], picked)

    choice = picked % P_PAD                     # un-embed candidate index
    out_ref[0, :] = jnp.where(num_valid > 0, choice, -1)


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def lcmp_decide(flow_ids: jnp.ndarray, c_path: jnp.ndarray, c_cong: jnp.ndarray,
                valid: jnp.ndarray, params: SelectParams = SelectParams(),
                interpret: bool = True) -> jnp.ndarray:
    """Batched LCMP decision. flow_ids (F,) uint32; c_path/c_cong/valid
    (F, P) with P <= 8. Returns (F,) int32 candidate indices (-1: none)."""
    F, P = c_path.shape
    assert P <= P_PAD, "switch candidate sets are m<=8 (paper §4)"
    f_pad = (F + BF - 1) // BF * BF

    def pad_fp(x, fill):
        x = jnp.pad(x.astype(jnp.int32), ((0, f_pad - F), (0, P_PAD - P)),
                    constant_values=fill)
        return x.T.reshape(P_PAD, f_pad)        # candidates -> sublanes

    fid = jnp.pad(flow_ids.astype(jnp.uint32), (0, f_pad - F)).reshape(1, f_pad)
    cp = pad_fp(c_path, 0)
    cc = pad_fp(c_cong, 0)
    vd = pad_fp(valid.astype(jnp.int32), 0)

    grid = (f_pad // BF,)
    kern = functools.partial(
        _decide_kernel, alpha=params.alpha, beta=params.beta,
        keep_num=params.keep_num, cong_fallback=params.cong_fallback)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BF), lambda i: (0, i), memory_space=pltpu.VMEM),
            pl.BlockSpec((P_PAD, BF), lambda i: (0, i), memory_space=pltpu.VMEM),
            pl.BlockSpec((P_PAD, BF), lambda i: (0, i), memory_space=pltpu.VMEM),
            pl.BlockSpec((P_PAD, BF), lambda i: (0, i), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, BF), lambda i: (0, i), memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((1, f_pad), jnp.int32),
        interpret=interpret,
        name="lcmp_decide",
    )(fid, cp, cc, vd)
    return out[0, :F]
