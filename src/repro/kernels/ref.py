"""Pure-jnp oracles for every Pallas kernel in this package.

These are the *reference semantics*; kernels must match them bit-exactly
(integer paths) or to float tolerance (quantizer). The LCMP decision
oracle reuses repro.core.select so the kernel is pinned to the very same
semantics the rest of the framework (netsim, collective scheduler) uses.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import cong as congmod
from repro.core import select as selmod
from repro.core.cong import CongParams, CongState
from repro.core.select import SelectParams
from repro.core.tables import SwitchTables


def lcmp_decide_ref(flow_ids: jnp.ndarray, c_path: jnp.ndarray,
                    c_cong: jnp.ndarray, valid: jnp.ndarray,
                    params: SelectParams = SelectParams()) -> jnp.ndarray:
    """(F,), (F,P), (F,P), (F,P) -> (F,) candidate index (-1 if none)."""
    idx, _ = selmod.select_egress(flow_ids, c_path, c_cong, valid, params)
    return idx


def cong_update_ref(state: CongState, queue_cells: jnp.ndarray, now_us,
                    tables: SwitchTables, params: CongParams = CongParams()):
    """Monitor tick + score derivation. Returns (state', c_cong)."""
    st = congmod.monitor_update(state, queue_cells, now_us, tables, params)
    return st, congmod.calc_cong_cost(st, tables, params)


def qsr_int8_ref(x: jnp.ndarray, rand_bits: jnp.ndarray, block: int = 1024):
    """Blockwise int8 quantization with stochastic rounding.

    x: (N,) float32 (N multiple of block); rand_bits: (N,) uint32.
    Returns (q int8 (N,), scales float32 (N/block,)).
    """
    n = x.shape[0]
    xb = x.reshape(n // block, block)
    amax = jnp.max(jnp.abs(xb), axis=1, keepdims=True)
    scale = amax / 127.0
    inv = jnp.where(amax > 0, 127.0 / amax, 0.0)
    y = xb * inv
    u = (rand_bits.reshape(n // block, block) >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    q = jnp.clip(jnp.floor(y + u), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale[:, 0]


def qsr_dequant_ref(q: jnp.ndarray, scales: jnp.ndarray, block: int = 1024):
    n = q.shape[0]
    return (q.reshape(n // block, block).astype(jnp.float32)
            * scales[:, None]).reshape(n)
