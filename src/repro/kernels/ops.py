"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (this container) and False on
real TPU backends — callers can force either. All wrappers share
signatures with the pure-jnp oracles in ref.py.
"""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.lcmp_decide import lcmp_decide as _lcmp_decide
from repro.kernels.cong_update import cong_update as _cong_update
from repro.kernels.qsr_int8 import qsr_int8 as _qsr_int8, qsr_dequant as _qsr_dequant


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def lcmp_decide(flow_ids, c_path, c_cong, valid, params=None, interpret=None):
    from repro.core.select import SelectParams
    params = params or SelectParams()
    interpret = _default_interpret() if interpret is None else interpret
    if c_path.shape[-1] > 8:     # paper bounds m<=8; larger sets use the oracle
        return ref.lcmp_decide_ref(flow_ids, c_path, c_cong, valid, params)
    return _lcmp_decide(flow_ids, c_path, c_cong, valid, params, interpret)


def cong_update(state, queue_cells, now_us, tables, params=None, interpret=None):
    from repro.core.cong import CongParams
    params = params or CongParams()
    interpret = _default_interpret() if interpret is None else interpret
    return _cong_update(state, queue_cells, now_us, tables, params, interpret)


def qsr_int8(x, rand_bits, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _qsr_int8(x, rand_bits, interpret)


def qsr_dequant(q, scales, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _qsr_dequant(q, scales, interpret)
