"""Single-token decode with per-family caches (serve_step).

Cache layout (leaves stacked over layers, scanned like the params):
- attention : k/v (L, B, Smax, Kv, hd)
- mamba1    : conv (L, B, 3, Di), ssm (L, B, Di, N)
- mamba2    : conv (L, B, 3, Di+2N), ssm (L, B, H, N, P)
- zamba shared attention: one k/v cache per application site
- encdec    : decoder self-attn caches + precomputed cross k/v

``decode_32k`` / ``long_500k`` shapes lower exactly this step: one new
token against a seq_len-sized cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.arch import ArchConfig
from repro.models import arch as _archmod


# ----------------------------------------------------------------- caches
def init_cache(cfg: ArchConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or cfg.adt
    Lx, B, Kv, hd = cfg.n_layers, batch, cfg.n_kv, cfg.hd
    Di = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state

    def kv(n, s):
        return dict(k=jnp.zeros((n, B, s, Kv, hd), dtype),
                    v=jnp.zeros((n, B, s, Kv, hd), dtype))

    if cfg.family in ("dense", "moe", "vlm"):
        return dict(attn=kv(Lx, max_seq))
    if cfg.family == "ssm":
        return dict(conv=jnp.zeros((Lx, B, 3, Di), dtype),
                    ssm=jnp.zeros((Lx, B, Di, N), jnp.float32))
    if cfg.family == "hybrid":
        H = Di // 64
        sites = (cfg.n_layers + cfg.shared_attn_every - 1) \
            // cfg.shared_attn_every if cfg.shared_attn_every else 0
        return dict(conv=jnp.zeros((Lx, B, 3, Di + 2 * N), dtype),
                    ssm=jnp.zeros((Lx, B, H, N, 64), jnp.float32),
                    shared=kv(max(sites, 1), max_seq))
    if cfg.family == "encdec":
        return dict(attn=kv(Lx, max_seq), cross=kv(Lx, cfg.enc_seq))
    raise ValueError(cfg.family)


def prefill_cross_cache(params, cfg: ArchConfig, enc_out):
    """Precompute encoder-side K/V for whisper cross-attention."""
    def one(lp):
        k = jnp.einsum("bsd,de->bse", enc_out, lp["xattn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,de->bse", enc_out, lp["xattn"]["wv"].astype(enc_out.dtype))
        B, S, _ = enc_out.shape
        return dict(k=k.reshape(B, S, cfg.n_kv, cfg.hd),
                    v=v.reshape(B, S, cfg.n_kv, cfg.hd))
    return jax.vmap(one, in_axes=0)(params["layers"])


# ------------------------------------------------------------ attn decode
def _attn_decode(p, cfg: ArchConfig, x, kc, vc, pos, *, local=False,
                 cross=False, use_rope=True):
    """x: (B,1,D); kc/vc: (B,Smax,Kv,hd). Returns (y, kc, vc)."""
    B = x.shape[0]
    h = L.rms_norm(x, p["ln"])
    q = jnp.einsum("bsd,de->bse", h, p["wq"].astype(h.dtype))
    q = q.reshape(B, 1, cfg.n_heads, cfg.hd)
    if not cross:
        k = jnp.einsum("bsd,de->bse", h, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,de->bse", h, p["wv"].astype(h.dtype))
        k = k.reshape(B, 1, cfg.n_kv, cfg.hd)
        v = v.reshape(B, 1, cfg.n_kv, cfg.hd)
        if cfg.qk_norm:
            q = L.rms_norm(q, p["q_norm"])
            k = L.rms_norm(k, p["k_norm"])
        if use_rope:
            pp = jnp.full((B, 1), pos)
            q = L.rope(q, pp, cfg.rope_theta)
            k = L.rope(k, pp, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
    elif cfg.qk_norm:
        q = L.rms_norm(q, p["q_norm"])

    Smax = kc.shape[1]
    g = cfg.n_heads // cfg.n_kv
    qg = q.reshape(B, 1, cfg.n_kv, g, cfg.hd)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc).astype(jnp.float32)
    logits = logits / jnp.sqrt(cfg.hd).astype(jnp.float32)
    if cfg.attn_softcap:
        logits = jnp.tanh(logits / cfg.attn_softcap) * cfg.attn_softcap
    kpos = jnp.arange(Smax)
    mask = jnp.ones((Smax,), bool) if cross else (kpos <= pos)
    if local and cfg.window and not cross:
        mask &= kpos > pos - cfg.window
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, -1).astype(x.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", probs, vc)
    o = o.reshape(B, 1, cfg.n_heads * cfg.hd)
    y = x + jnp.einsum("bse,ed->bsd", o, p["wo"].astype(h.dtype))
    return y, kc, vc


# ----------------------------------------------------------- mamba decode
def _mamba1_decode(p, cfg, x, conv, ssm):
    B = x.shape[0]
    h = L.rms_norm(x, p["ln"])[:, 0]
    Di = p["A_log"].shape[0]
    xz = jnp.einsum("bd,de->be", h, p["in_proj"].astype(h.dtype))
    xi, z = jnp.split(xz, 2, -1)
    k = p["conv_w"].astype(h.dtype)
    hist = jnp.concatenate([conv, xi[:, None, :]], 1)           # (B,4,Di)
    xi = jax.nn.silu(jnp.einsum("bki,ki->bi", hist, k))
    conv = hist[:, 1:]
    dt_rank = p["dt_proj"].shape[0]
    N = p["A_log"].shape[1]
    proj = jnp.einsum("bi,ie->be", xi, p["x_proj"].astype(h.dtype))
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], -1)
    dt = jax.nn.softplus(jnp.einsum("br,ri->bi", dt, p["dt_proj"].astype(h.dtype)))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt.astype(jnp.float32)[..., None] * A)
    dBx = (dt * xi).astype(jnp.float32)[..., None] * Bc.astype(jnp.float32)[:, None, :]
    ssm = ssm * dA + dBx
    y = jnp.einsum("bin,bn->bi", ssm, Cc.astype(jnp.float32)).astype(h.dtype)
    y = y + xi * p["D_skip"].astype(h.dtype)
    y = y * jax.nn.silu(z)
    return x + jnp.einsum("bi,id->bd", y, p["out_proj"].astype(h.dtype))[:, None], conv, ssm


def _mamba2_decode(p, cfg, x, conv, ssm):
    B = x.shape[0]
    h = L.rms_norm(x, p["ln"])[:, 0]
    Di = p["norm_scale"].shape[0]
    H = p["A_log"].shape[0]
    P = Di // H
    N = (p["in_proj"].shape[1] - 2 * Di - H) // 2
    zxbcdt = jnp.einsum("bd,de->be", h, p["in_proj"].astype(h.dtype))
    z, xbc, dt = jnp.split(zxbcdt, [Di, 2 * Di + 2 * N], -1)
    k = p["conv_w"].astype(h.dtype)
    hist = jnp.concatenate([conv, xbc[:, None, :]], 1)
    xbc = jax.nn.silu(jnp.einsum("bki,ki->bi", hist, k))
    conv = hist[:, 1:]
    xi, Bc, Cc = jnp.split(xbc, [Di, Di + N], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32))                # (B,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                        # (B,H)
    xh = xi.reshape(B, H, P).astype(jnp.float32)
    dBx = dt[..., None, None] * Bc.astype(jnp.float32)[:, None, :, None] \
        * xh[:, :, None, :]                                     # (B,H,N,P)
    ssm = ssm * dA[..., None, None] + dBx
    y = jnp.einsum("bhnp,bn->bhp", ssm, Cc.astype(jnp.float32))
    y = y + xh * p["D_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, Di).astype(h.dtype)
    y = L.rms_norm(y * jax.nn.silu(z), p["norm_scale"])
    return x + jnp.einsum("bi,id->bd", y, p["out_proj"].astype(h.dtype))[:, None], conv, ssm


# -------------------------------------------------------------- serve step
def decode_step(params, cfg: ArchConfig, cache, tokens, pos):
    """tokens (B,1) int32, pos: scalar int32 -> (logits (B,1,V), cache')."""
    x = params["embed"][tokens].astype(cfg.adt)
    if cfg.family == "dense" and cfg.name.startswith("gemma"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.adt)

    fam = cfg.family
    every = cfg.shared_attn_every
    shared = params.get("shared_attn")

    if fam in ("dense", "moe", "vlm"):
        from repro.models.arch import _mlp_apply, _moe_apply

        def layer(carry, xs):
            h = carry
            lp, kc, vc, idx = xs
            if cfg.alt_local_global:
                h, kc, vc = _attn_decode(lp["attn"], cfg, h, kc, vc, pos,
                                         local=False)  # pairs handled below
            else:
                h, kc, vc = _attn_decode(lp["attn"], cfg, h, kc, vc, pos,
                                         local=bool(cfg.window))
            if fam == "moe":
                h = _moe_apply(lp["moe"], h, cfg)
            else:
                h = _mlp_apply(lp["mlp"], h)
            return h, dict(k=kc, v=vc)

        if cfg.alt_local_global:
            # static local/global alternation: scan layer *pairs*
            def pair(carry, xs):
                h = carry
                lp, kc, vc, idx = xs
                lp0 = jax.tree.map(lambda a: a[0], lp)
                lp1 = jax.tree.map(lambda a: a[1], lp)
                h, k0, v0 = _attn_decode(lp0["attn"], cfg, h, kc["0"], vc["0"],
                                         pos, local=True)
                h = _mlp_or_moe(lp0, h, cfg)
                h, k1, v1 = _attn_decode(lp1["attn"], cfg, h, kc["1"], vc["1"],
                                         pos, local=False)
                h = _mlp_or_moe(lp1, h, cfg)
                return h, dict(k={"0": k0, "1": k1}, v={"0": v0, "1": v1})

            def _mlp_or_moe(lp, h, cfg):
                return _moe_apply(lp["moe"], h, cfg) if fam == "moe" \
                    else _mlp_apply(lp["mlp"], h)

            np2 = cfg.n_layers // 2
            lp_pairs = jax.tree.map(
                lambda a: a.reshape(np2, 2, *a.shape[1:]), params["layers"])
            kcp = {"0": cache["attn"]["k"][0::2], "1": cache["attn"]["k"][1::2]}
            vcp = {"0": cache["attn"]["v"][0::2], "1": cache["attn"]["v"][1::2]}
            x, kv_new = _archmod._scan(
                pair, x, (lp_pairs, kcp, vcp, jnp.arange(np2)))
            k_all = jnp.stack([kv_new["k"]["0"], kv_new["k"]["1"]], 1) \
                .reshape(cfg.n_layers, *cache["attn"]["k"].shape[1:])
            v_all = jnp.stack([kv_new["v"]["0"], kv_new["v"]["1"]], 1) \
                .reshape(cfg.n_layers, *cache["attn"]["v"].shape[1:])
            cache = dict(attn=dict(k=k_all, v=v_all))
        else:
            x, kv_new = _archmod._scan(
                layer, x,
                (params["layers"], cache["attn"]["k"], cache["attn"]["v"],
                 jnp.arange(cfg.n_layers)))
            cache = dict(attn=kv_new)

    elif fam == "ssm":
        def layer(h, xs):
            lp, conv, ssm = xs
            h, conv, ssm = _mamba1_decode(lp["mamba"], cfg, h, conv, ssm)
            return h, (conv, ssm)
        x, (conv, ssm) = _archmod._scan(
            layer, x, (params["layers"], cache["conv"], cache["ssm"]))
        cache = dict(conv=conv, ssm=ssm)

    elif fam == "hybrid":
        sites = cache["shared"]["k"].shape[0]
        site_of_layer = jnp.arange(cfg.n_layers) // max(every, 1)

        def layer(carry, xs):
            h, sk, sv = carry
            lp, conv, ssm, idx = xs

            def with_attn(args):
                h, sk, sv = args
                site = site_of_layer[idx]
                kc = jax.lax.dynamic_index_in_dim(sk, site, 0, keepdims=False)
                vc = jax.lax.dynamic_index_in_dim(sv, site, 0, keepdims=False)
                h2, kc, vc = _attn_decode(shared, cfg, h, kc, vc, pos)
                sk2 = jax.lax.dynamic_update_index_in_dim(sk, kc, site, 0)
                sv2 = jax.lax.dynamic_update_index_in_dim(sv, vc, site, 0)
                return h2, sk2, sv2

            use = (every > 0) & (jnp.mod(idx, max(every, 1)) == 0)
            h, sk, sv = jax.lax.cond(use, with_attn, lambda a: a, (h, sk, sv))
            h, conv, ssm = _mamba2_decode(lp["mamba"], cfg, h, conv, ssm)
            return (h, sk, sv), (conv, ssm)

        (x, sk, sv), (conv, ssm) = _archmod._scan(
            layer, (x, cache["shared"]["k"], cache["shared"]["v"]),
            (params["layers"], cache["conv"], cache["ssm"],
             jnp.arange(cfg.n_layers)))
        cache = dict(conv=conv, ssm=ssm, shared=dict(k=sk, v=sv))

    elif fam == "encdec":
        from repro.models.arch import _mlp_apply

        def layer(h, xs):
            lp, kc, vc, xk, xv = xs
            h, kc, vc = _attn_decode(lp["attn"], cfg, h, kc, vc, pos,
                                     use_rope=False)
            h, _, _ = _attn_decode(lp["xattn"], cfg, h, xk, xv, pos,
                                   cross=True, use_rope=False)
            h = _mlp_apply(lp["mlp"], h)
            return h, dict(k=kc, v=vc)

        x, kv_new = _archmod._scan(
            layer, x, (params["layers"], cache["attn"]["k"],
                       cache["attn"]["v"], cache["cross"]["k"],
                       cache["cross"]["v"]))
        cache = dict(attn=kv_new, cross=cache["cross"])
    else:
        raise ValueError(fam)

    x = L.rms_norm(x, params["final_ln"])
    logits = jnp.einsum("bsd,vd->bsv", x, params["lm_head"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits, cache
