"""Flow-size distributions (paper §6: Web Search, Facebook Hadoop,
Alibaba Storage), as piecewise-linear CDFs.

The breakpoints follow the CDF files shipped with the DCQCN/HPCC
simulation artifacts (traffic_gen/flowCDF in the paper's own repo);
values are the standard published curves re-entered from the literature
(DCTCP for WebSearch, Roy et al. for FB Hadoop, HPCC for AliStorage).
Sampling inverts the CDF with linear interpolation in log-size space.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class SizeCDF:
    name: str
    sizes: np.ndarray   # bytes, increasing
    probs: np.ndarray   # cdf in [0,1], increasing, ends at 1

    def mean(self) -> float:
        """Exact mean of the sampled distribution: within a CDF segment
        the size is log-linear in u (see ``sample``), so the conditional
        mean is the *logarithmic* mean of the endpoints,
        ``(s1 - s0) / ln(s1/s0)`` — not the arithmetic midpoint, which
        belongs to linear-size interpolation and overstates every
        segment. Load calibration divides by this, so the two must agree
        or every "x% load" run is silently mis-dosed."""
        s0, s1 = self.sizes[:-1], self.sizes[1:]
        w = np.diff(self.probs)
        with np.errstate(divide="ignore", invalid="ignore"):
            logmean = np.where(np.isclose(s0, s1), s0,
                               (s1 - s0) / np.log(s1 / s0))
        return float((logmean * w).sum() + self.sizes[0] * self.probs[0])

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Invert the CDF with linear interpolation in log-size space.

        The published breakpoints are log-spaced samples of smooth
        heavy-tailed curves; linear-size interpolation within a segment
        like [1 MB, 10 MB) puts half the segment's mass above 5.5 MB
        (the tail draws bias large), where the curves' own log-linear
        shape puts the median near the geometric mean ~3.2 MB."""
        u = rng.uniform(0, 1, n)
        return np.exp(np.interp(u, self.probs, np.log(self.sizes)))


WEB_SEARCH = SizeCDF(
    "WebSearch",
    sizes=np.array([1e3, 2e3, 3e3, 5e3, 7e3, 1e4, 2e4, 3e4, 5e4, 8e4,
                    2e5, 1e6, 2e6, 5e6, 1e7, 3e7], float),
    probs=np.array([0.00, 0.15, 0.30, 0.40, 0.53, 0.60, 0.70, 0.72, 0.82,
                    0.87, 0.91, 0.95, 0.97, 0.99, 0.997, 1.0], float),
)

FB_HADOOP = SizeCDF(
    "FbHdp",
    sizes=np.array([1e2, 2e2, 3.5e2, 5e2, 1e3, 2e3, 5e3, 1e4, 4e4,
                    1e5, 1e6, 1e7], float),
    probs=np.array([0.00, 0.20, 0.40, 0.50, 0.60, 0.70, 0.78, 0.82, 0.87,
                    0.90, 0.95, 1.0], float),
)

ALI_STORAGE = SizeCDF(
    "AliStorage",
    sizes=np.array([2e2, 1e3, 4e3, 1.6e4, 6.4e4, 2.56e5, 1e6, 4e6,
                    1.6e7, 6.4e7], float),
    probs=np.array([0.00, 0.30, 0.50, 0.60, 0.70, 0.80, 0.90, 0.95,
                    0.99, 1.0], float),
)

WORKLOADS: Dict[str, SizeCDF] = {
    "websearch": WEB_SEARCH,
    "fbhdp": FB_HADOOP,
    "alistorage": ALI_STORAGE,
}
