"""Synthetic inter-DC traffic generation (paper §6 workloads).

Given a topology's path table, a size CDF, and a target average
utilization rho, generate Poisson flow arrivals across the requested
pairs (all-to-all, a single DC pair for the testbed experiments, or a
foreground pair measured under background cross-traffic).

Load calibration follows the standard FCT-benchmark convention, applied
**per pair** (see ``dose_bases``): each pair's arrival byte-rate equals
``rho x (number of distinct first-hop links among its candidates) x
min(first-hop cap / sharing)`` — under ECMP each of the N first-hop
links carries total/N and the smallest link is the binding constraint,
so this is the rho that makes the *ideal* placement run the pair's
bottleneck class at the requested utilization; ``sharing`` splits each
first-hop link's budget across the dosed pairs using it, so all-to-all
grids don't double-count shared links. (Check: 30% on the 8-DC
testbed -> 6 x 40 G x 0.3 = 72 Gbps total -> 200G links at 6%, 40G
links at 30% under ECMP — exactly the paper's quoted Fig. 1b values.)

Historically all requested pairs shared ONE aggregate budget computed
off the *global* min first-hop capacity with flows assigned to pairs
uniformly — on a heterogeneous WAN that under-doses every fat pair and
over-doses every thin one. Each pair now runs its own independent
Poisson process against its own bottleneck class, and the generator
reports the per-pair target and realized byte-rates (``dose_*`` fields)
so benchmarks can assert dosing accuracy instead of trusting it.

``bg_pair_ids``/``bg_load`` add background cross-traffic: those pairs
are dosed at ``bg_load`` while the requested pairs run at ``load``, and
``FlowSet.fg_mask`` marks which flows belong to the measured foreground
set (see ``metrics.fg_bg_stats``).

``sched_t``/``load_rows``/``bg_rows`` promote each pair's dose from a
static scalar to a piecewise-constant **load schedule** (diurnal sine
curves phase-shifted by DC timezone, flash crowds, traffic-matrix
shifts — built by ``traffic.sched``). Non-constant rows run a
non-homogeneous Poisson process by thinning; constant rows take the
legacy homogeneous draw path bit-for-bit, so the schedule machinery is
a strict superset of the scalar interface.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.netsim.paths import PathTable
from repro.traffic.cdf import SizeCDF


@dataclasses.dataclass(frozen=True)
class FlowSet:
    """Flat arrays describing all flows of one experiment (numpy)."""
    arrival_us: np.ndarray   # (F,) int64, sorted
    size_bytes: np.ndarray   # (F,) float64
    pair_id: np.ndarray      # (F,) int32 index into PathTable pair_*
    flow_id: np.ndarray      # (F,) uint32 (hash key)
    # foreground-pair membership (None == all foreground, legacy callers)
    fg_mask: Optional[np.ndarray] = None      # (F,) bool
    # multi-subflow transports (amp): row -> parent-flow index. None for
    # ordinary one-flow-per-row sets; when set, metrics score the PARENT
    # (done = all subflows done, FCT = last subflow, size = sum).
    subflow_of: Optional[np.ndarray] = None   # (F,) int32
    # co-simulated collective rows (repro.cosim): row -> index into the
    # CosimPlan's bucket-flow arrays, -1 for ordinary (background) rows.
    # None for sets with no overlay — the legacy wire shape exactly.
    cosim_of: Optional[np.ndarray] = None     # (F,) int32
    # dosing telemetry, one row per dosed pair (None for hand-built sets)
    dose_pair: Optional[np.ndarray] = None    # (P,) int32 pair ids
    dose_target: Optional[np.ndarray] = None  # (P,) float64 target bytes/us
    dose_real: Optional[np.ndarray] = None    # (P,) float64 realized bytes/us

    @property
    def num_flows(self) -> int:
        return len(self.arrival_us)

    @property
    def foreground(self) -> np.ndarray:
        """(F,) bool — True for flows of the measured (foreground) pairs."""
        if self.fg_mask is None:
            return np.ones(self.num_flows, bool)
        return self.fg_mask

    def dosing_error(self) -> float:
        """|realized - target| / target over the aggregate byte-rate —
        the offered-load accuracy benchmarks assert (NaN if untracked)."""
        if self.dose_target is None or self.dose_target.sum() <= 0:
            return float("nan")
        tot_t = float(self.dose_target.sum())
        tot_r = float(self.dose_real.sum())
        return abs(tot_r - tot_t) / tot_t


def dose_bases(table: PathTable, pair_ids) -> np.ndarray:
    """Per-pair calibration bases in Gbps for a *jointly dosed* pair set.

    A pair's basis is ``N_first_hops x min(first-hop cap / sharing)``
    over its candidate paths — the byte budget that runs the pair's own
    bottleneck class at 100% under ideal (ECMP-even) placement, where
    ``sharing`` divides each first-hop link's capacity by the number of
    dosed pairs using it as a first hop. Without the sharing split an
    all-to-all workload double-counts every shared link (two pairs each
    dosing the same 400G chord at its full capacity oversubscribes the
    network at nominal "30% load"); with it, a single-pair run reduces
    to the classic ``N x min(cap)`` convention unchanged."""
    pair_ids = np.asarray(pair_ids, np.int32)
    use: dict = {}         # first-hop link -> number of dosed pairs on it
    per_pair = []          # per pair: {first-hop link: bottleneck cap}
    for pid in pair_ids:
        links = {}
        for k in range(int(table.pair_ncand[pid])):
            p = int(table.pair_cand[pid, k])
            links[int(table.path_first[p])] = int(table.path_cap[p])
        if not links:
            raise ValueError(f"pair {int(pid)} has no installed candidate "
                             "paths")
        per_pair.append(links)
        for li in links:
            use[li] = use.get(li, 0) + 1
    return np.array([len(links) * min(c / use[li]
                                      for li, c in links.items())
                     for links in per_pair], np.float64)


def pair_dose_basis(table: PathTable, pid: int) -> float:
    """Single-pair basis (no sharing): ``N_first_hops x min cap``."""
    return float(dose_bases(table, [pid])[0])


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    """numpy twin of ``core.select.fmix32`` (MurmurHash3 finalizer)."""
    x = x.astype(np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def _split_subflows(arrivals, sizes, pids, fids, fg, k: int):
    """AMP-style multi-subflow expansion: each parent flow becomes ``k``
    subflows of ``size/k`` arriving together, each with its own
    deterministic hash key derived from the parent id (distinct keys are
    what makes the subflows route independently under hash-based
    policies). Returns the expanded arrays plus the ``subflow_of``
    row -> parent map metrics use to score the parent at last-subflow
    completion. Runs AFTER the rng draw sequence is complete, so the
    ``n_subflows=1`` path stays bit-for-bit identical to legacy output."""
    n = len(arrivals)
    rep = lambda a: np.repeat(a, k)
    sub_k = np.tile(np.arange(k, dtype=np.uint32), n)
    sub_fid = _fmix32_np(rep(fids) ^ (sub_k * np.uint32(0x9E3779B9)))
    sub_fid = np.where(sub_fid == 0, np.uint32(1), sub_fid)  # ids stay nonzero
    return (rep(arrivals), rep(sizes) / k, rep(pids), sub_fid, rep(fg),
            np.repeat(np.arange(n, dtype=np.int32), k))


def _poisson_window(rng: np.random.Generator, lam: float,
                    duration_us: int) -> np.ndarray:
    """Arrival times of one Poisson process covering the FULL window.

    Draws ``1.2x expected + 64`` exponential gaps up front and tops up
    until the cumulative sum passes ``duration_us`` — the window is
    covered by construction, never silently cut short."""
    n = int(lam * duration_us * 1.2) + 64
    arr = np.cumsum(rng.exponential(1.0 / lam, n))
    while arr[-1] < duration_us:          # top-up (vanishingly rare)
        more = rng.exponential(1.0 / lam, max(n // 4, 64))
        arr = np.concatenate([arr, arr[-1] + np.cumsum(more)])
    return arr[arr < duration_us * 1e0]


def _poisson_sched(rng: np.random.Generator, lam_row: np.ndarray,
                   sched_t: np.ndarray, duration_us: int) -> np.ndarray:
    """Arrival times of a piecewise-constant non-homogeneous Poisson
    process: rate ``lam_row[k]`` (flows/us) over segment ``k`` starting
    at ``sched_t[k]``.

    Implemented by thinning: draw a homogeneous process at ``max(lam)``
    (the exact legacy ``_poisson_window`` draws), then accept each
    arrival with probability ``lam(t) / max(lam)`` using ONE uniform
    draw per candidate. A *constant* row takes the homogeneous path with
    zero extra draws — that branch is what keeps constant-schedule
    output bit-for-bit identical to the legacy scalar-``load`` path.
    All-zero rows draw nothing."""
    lam_max = float(lam_row.max())
    if lam_max <= 0.0:
        return np.zeros(0, np.float64)
    if float(lam_row.min()) == lam_max:    # constant: legacy draws exactly
        return _poisson_window(rng, lam_max, duration_us)
    arr = _poisson_window(rng, lam_max, duration_us)
    seg = np.searchsorted(sched_t, arr, side="right") - 1
    keep = rng.random(len(arr)) * lam_max < lam_row[seg]
    return arr[keep]


def generate(table: PathTable, cdf: SizeCDF, load: float, duration_us: int,
             pair_ids=None, seed: int = 0, max_flows: int = 200_000,
             cap_scale: float = 1.0, bg_pair_ids=None,
             bg_load: float = 0.0, n_subflows: int = 1,
             sched_t=None, load_rows=None, bg_rows=None) -> FlowSet:
    """Poisson arrivals at per-pair utilization ``load`` over
    ``duration_us`` (plus optional ``bg_load`` cross-traffic on
    ``bg_pair_ids``).

    ``sched_t``/``load_rows``/``bg_rows`` (optional, built by
    ``traffic.sched.build``) promote the per-pair dose from a scalar to
    a **piecewise-constant load schedule**: ``sched_t`` is a shared
    (K,) grid of segment start times (``sched_t[0] == 0``, ascending)
    and ``load_rows[i, k]`` / ``bg_rows[j, k]`` the load *multiplier* of
    foreground pair ``pair_ids[i]`` / background pair ``bg_pair_ids[j]``
    over segment ``k`` — the effective utilization of pair ``i`` during
    segment ``k`` is ``load * load_rows[i, k]``. Arrivals follow a
    non-homogeneous Poisson process via thinning (``_poisson_sched``);
    a pair whose row is constant takes the exact legacy homogeneous
    draw path, so all-ones rows reproduce scalar-``load`` output
    **bit-for-bit**. Dose telemetry targets become the schedule's
    time-average byte-rate.

    ``cap_scale`` must match the simulator's capacity scale so the
    offered byte rate targets the *simulated* capacities. Raises
    ``ValueError`` when the requested load needs more than ``max_flows``
    flows — the pre-fix behavior silently cut the *end* of the arrival
    window instead, simulating less offered load than requested.
    """
    rng = np.random.default_rng(seed)
    if pair_ids is None:
        pair_ids = np.arange(len(table.pair_src))
    pair_ids = np.asarray(pair_ids, np.int32)
    bg_pair_ids = (np.zeros(0, np.int32) if bg_pair_ids is None or bg_load <= 0
                   else np.asarray(bg_pair_ids, np.int32))
    keep_bg = ~np.isin(bg_pair_ids, pair_ids)
    bg_pair_ids = bg_pair_ids[keep_bg]

    if sched_t is None:
        sched_t = np.zeros(1, np.int64)
        load_rows = np.ones((len(pair_ids), 1), np.float64)
        bg_rows = np.ones((len(bg_pair_ids), 1), np.float64)
    else:
        sched_t = np.asarray(sched_t, np.int64)
        if sched_t[0] != 0 or np.any(np.diff(sched_t) <= 0):
            raise ValueError("sched_t must start at 0 and be strictly "
                             "ascending")
        load_rows = np.asarray(load_rows, np.float64)
        if bg_rows is None or len(bg_pair_ids) == 0:
            bg_rows = np.ones((len(bg_pair_ids), len(sched_t)))
        else:            # rows align with the caller's UNfiltered bg list
            bg_rows = np.asarray(bg_rows, np.float64)[keep_bg]
        if load_rows.shape != (len(pair_ids), len(sched_t)) or \
                bg_rows.shape != (len(bg_pair_ids), len(sched_t)):
            raise ValueError(
                f"schedule rows must be (pairs, {len(sched_t)}): got "
                f"{load_rows.shape} fg / {bg_rows.shape} bg")
        if load_rows.min(initial=0.0) < 0 or bg_rows.min(initial=0.0) < 0:
            raise ValueError("schedule rows must be non-negative")
    # per-segment durations (last segment runs to the end of the window)
    seg_dur = np.diff(np.append(sched_t, duration_us)).astype(np.float64)

    mean_size = cdf.mean()
    doses = [(int(p), float(load) * load_rows[i], True)
             for i, p in enumerate(pair_ids)] + \
            [(int(p), float(bg_load) * bg_rows[j], False)
             for j, p in enumerate(bg_pair_ids)]
    # first-hop sharing is split WITHIN each dose group: the foreground
    # pairs divide capacity among themselves (all-to-all stays sane) but
    # keep their full class against the background set — cross-traffic is
    # the interference being measured, not a reason to dose the measured
    # pair less
    bases = np.concatenate([
        dose_bases(table, pair_ids),
        dose_bases(table, bg_pair_ids) if len(bg_pair_ids) else np.zeros(0)])
    # (K,) flows/us rate row per pair; lam_avg is its time average —
    # for a constant row this is the legacy scalar lam exactly
    lams = {p: row * base * 125.0 * cap_scale / mean_size
            for (p, row, _), base in zip(doses, bases)}
    lam_avg = {p: float((lams[p] * seg_dur).sum()) / duration_us
               for p, _, _ in doses}

    expect = (sum(int(lam_avg[p] * duration_us * 1.2) + 64
                  for p, _, _ in doses) * max(int(n_subflows), 1))
    if expect > max_flows:
        raise ValueError(
            f"offered load needs ~{expect} flows but max_flows={max_flows}: "
            f"the arrival window would be silently truncated (under-dosed). "
            f"Raise max_flows (>= {expect}) or chunk the run into shorter "
            f"duration_us segments.")

    row0 = doses[0][1] if doses else np.zeros(1)
    if len(doses) == 1 and doses[0][2] and \
            float(row0.min()) == float(row0.max()) and row0.max() > 0:
        # single foreground pair with a constant (or absent) schedule:
        # keep the exact legacy draw sequence (gaps -> sizes -> pair
        # assignment -> ids from one rng stream) so every pre-existing
        # single-pair experiment, tolerance band, and tuned acceptance
        # test stays bit-for-bit reproducible.
        pid = doses[0][0]
        # use the row's rate, NOT lam_avg: (lam * T) / T can differ from
        # lam by 1 ulp, which would desync the exponential draw stream
        arrivals = _poisson_window(rng, float(lams[pid].max()), duration_us)
        n = len(arrivals)
        sizes = cdf.sample(rng, n)
        pids = pair_ids[rng.integers(0, len(pair_ids), n)]
        fids = rng.integers(1, 1 << 32, n, dtype=np.uint32)
        fg = np.ones(n, bool)
        dose_real = np.array([sizes.sum() / duration_us])
    else:
        chunks = []
        for p, _, is_fg in doses:
            arr = _poisson_sched(rng, lams[p], sched_t, duration_us)
            chunks.append((p, is_fg, arr, cdf.sample(rng, len(arr))))
        # realized byte-rates straight off the per-pair chunks (no
        # per-flow remapping of the merged table needed)
        dose_real = np.array([s.sum() / duration_us
                              for _, _, _, s in chunks])
        arrivals = np.concatenate([a for _, _, a, _ in chunks])
        sizes = np.concatenate([s for _, _, _, s in chunks])
        pids = np.concatenate([np.full(len(a), p, np.int32)
                               for p, _, a, _ in chunks])
        fg = np.concatenate([np.full(len(a), is_fg)
                             for _, is_fg, a, _ in chunks])
        order = np.argsort(arrivals, kind="stable")
        arrivals, sizes, pids, fg = (arrivals[order], sizes[order],
                                     pids[order], fg[order])
        fids = rng.integers(1, 1 << 32, len(arrivals), dtype=np.uint32)

    dose_pair = np.array([p for p, _, _ in doses], np.int32)
    dose_target = np.array(    # schedule time-average byte-rate per pair
        [lam_avg[p] * mean_size for p, _, _ in doses], np.float64)

    # amp-style subflow expansion — after dose telemetry (byte rates are
    # a parent-level property, preserved exactly by the equal split) and
    # after every rng draw (the legacy draw sequence stays untouched)
    subflow_of = None
    if n_subflows > 1:
        (arrivals, sizes, pids, fids, fg,
         subflow_of) = _split_subflows(arrivals, sizes, pids, fids, fg,
                                       int(n_subflows))

    return FlowSet(arrival_us=arrivals.astype(np.int64),
                   size_bytes=sizes, pair_id=pids.astype(np.int32),
                   flow_id=fids, fg_mask=fg, subflow_of=subflow_of,
                   dose_pair=dose_pair, dose_target=dose_target,
                   dose_real=dose_real)
