"""Synthetic inter-DC traffic generation (paper §6 workloads).

Given a topology's path table, a size CDF, and a target average
utilization rho, generate Poisson flow arrivals "randomly pairing senders
and receivers" across the requested pairs (all-to-all, or a single DC
pair for the testbed experiments).

Load calibration follows the standard FCT-benchmark convention: the
aggregate arrival byte-rate equals ``rho x (sum of ideal-path bottleneck
capacities over distinct pairs, de-duplicated per first-hop link)`` —
i.e. rho is the average utilization the *ideal* placement would produce
on the long-haul links. This matches how traffic_gen.py in the paper's
artifact drives NS-3 (per-link utilization targets).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.paths import PathTable
from repro.traffic.cdf import SizeCDF


@dataclasses.dataclass(frozen=True)
class FlowSet:
    """Flat arrays describing all flows of one experiment (numpy)."""
    arrival_us: np.ndarray   # (F,) int64, sorted
    size_bytes: np.ndarray   # (F,) float64
    pair_id: np.ndarray      # (F,) int32 index into PathTable pair_*
    flow_id: np.ndarray      # (F,) uint32 (hash key)

    @property
    def num_flows(self) -> int:
        return len(self.arrival_us)


def generate(table: PathTable, cdf: SizeCDF, load: float, duration_us: int,
             pair_ids=None, seed: int = 0, max_flows: int = 200_000,
             cap_scale: float = 1.0) -> FlowSet:
    """Poisson arrivals at average utilization ``load`` over ``duration_us``.

    ``cap_scale`` must match the simulator's capacity scale so the offered
    byte rate targets the *simulated* capacities."""
    rng = np.random.default_rng(seed)
    if pair_ids is None:
        pair_ids = np.arange(len(table.pair_src))
    pair_ids = np.asarray(pair_ids, np.int32)

    # Load calibration: the paper's "x% load" reproduces its own Fig. 1b
    # utilization numbers only when normalized by the *bottleneck class*:
    # under ECMP each of the N first-hop links carries total/N, and the
    # smallest link is the binding constraint, so
    #    total_rate = load x N_first_hop_links x min(first-hop cap).
    # (Check: 30% on the 8-DC testbed -> 72 Gbps total -> 200G links at 6%,
    # 40G links at 30% under ECMP — exactly the paper's quoted values.)
    links_seen = {}
    for pid in pair_ids:
        for k in range(int(table.pair_ncand[pid])):
            p = int(table.pair_cand[pid, k])
            links_seen[int(table.path_first[p])] = int(table.path_cap[p])
    agg_gbps = len(links_seen) * min(links_seen.values())
    agg_Bpus = agg_gbps * 125.0 * cap_scale   # Gbps -> bytes/us (scaled)

    mean_size = cdf.mean()
    lam = load * agg_Bpus / mean_size          # flows per us, aggregate
    n = min(int(lam * duration_us * 1.2) + 64, max_flows)

    gaps = rng.exponential(1.0 / lam, n)
    arrivals = np.cumsum(gaps) * 1e0
    arrivals = arrivals[arrivals < duration_us * 1e0]
    n = len(arrivals)

    sizes = cdf.sample(rng, n)
    pids = pair_ids[rng.integers(0, len(pair_ids), n)]
    fids = rng.integers(1, 1 << 32, n, dtype=np.uint32)
    return FlowSet(arrival_us=arrivals.astype(np.int64),
                   size_bytes=sizes, pair_id=pids.astype(np.int32),
                   flow_id=fids)
