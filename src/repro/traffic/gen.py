"""Synthetic inter-DC traffic generation (paper §6 workloads).

Given a topology's path table, a size CDF, and a target average
utilization rho, generate Poisson flow arrivals across the requested
pairs (all-to-all, a single DC pair for the testbed experiments, or a
foreground pair measured under background cross-traffic).

Load calibration follows the standard FCT-benchmark convention, applied
**per pair** (see ``dose_bases``): each pair's arrival byte-rate equals
``rho x (number of distinct first-hop links among its candidates) x
min(first-hop cap / sharing)`` — under ECMP each of the N first-hop
links carries total/N and the smallest link is the binding constraint,
so this is the rho that makes the *ideal* placement run the pair's
bottleneck class at the requested utilization; ``sharing`` splits each
first-hop link's budget across the dosed pairs using it, so all-to-all
grids don't double-count shared links. (Check: 30% on the 8-DC
testbed -> 6 x 40 G x 0.3 = 72 Gbps total -> 200G links at 6%, 40G
links at 30% under ECMP — exactly the paper's quoted Fig. 1b values.)

Historically all requested pairs shared ONE aggregate budget computed
off the *global* min first-hop capacity with flows assigned to pairs
uniformly — on a heterogeneous WAN that under-doses every fat pair and
over-doses every thin one. Each pair now runs its own independent
Poisson process against its own bottleneck class, and the generator
reports the per-pair target and realized byte-rates (``dose_*`` fields)
so benchmarks can assert dosing accuracy instead of trusting it.

``bg_pair_ids``/``bg_load`` add background cross-traffic: those pairs
are dosed at ``bg_load`` while the requested pairs run at ``load``, and
``FlowSet.fg_mask`` marks which flows belong to the measured foreground
set (see ``metrics.fg_bg_stats``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.netsim.paths import PathTable
from repro.traffic.cdf import SizeCDF


@dataclasses.dataclass(frozen=True)
class FlowSet:
    """Flat arrays describing all flows of one experiment (numpy)."""
    arrival_us: np.ndarray   # (F,) int64, sorted
    size_bytes: np.ndarray   # (F,) float64
    pair_id: np.ndarray      # (F,) int32 index into PathTable pair_*
    flow_id: np.ndarray      # (F,) uint32 (hash key)
    # foreground-pair membership (None == all foreground, legacy callers)
    fg_mask: Optional[np.ndarray] = None      # (F,) bool
    # multi-subflow transports (amp): row -> parent-flow index. None for
    # ordinary one-flow-per-row sets; when set, metrics score the PARENT
    # (done = all subflows done, FCT = last subflow, size = sum).
    subflow_of: Optional[np.ndarray] = None   # (F,) int32
    # dosing telemetry, one row per dosed pair (None for hand-built sets)
    dose_pair: Optional[np.ndarray] = None    # (P,) int32 pair ids
    dose_target: Optional[np.ndarray] = None  # (P,) float64 target bytes/us
    dose_real: Optional[np.ndarray] = None    # (P,) float64 realized bytes/us

    @property
    def num_flows(self) -> int:
        return len(self.arrival_us)

    @property
    def foreground(self) -> np.ndarray:
        """(F,) bool — True for flows of the measured (foreground) pairs."""
        if self.fg_mask is None:
            return np.ones(self.num_flows, bool)
        return self.fg_mask

    def dosing_error(self) -> float:
        """|realized - target| / target over the aggregate byte-rate —
        the offered-load accuracy benchmarks assert (NaN if untracked)."""
        if self.dose_target is None or self.dose_target.sum() <= 0:
            return float("nan")
        tot_t = float(self.dose_target.sum())
        tot_r = float(self.dose_real.sum())
        return abs(tot_r - tot_t) / tot_t


def dose_bases(table: PathTable, pair_ids) -> np.ndarray:
    """Per-pair calibration bases in Gbps for a *jointly dosed* pair set.

    A pair's basis is ``N_first_hops x min(first-hop cap / sharing)``
    over its candidate paths — the byte budget that runs the pair's own
    bottleneck class at 100% under ideal (ECMP-even) placement, where
    ``sharing`` divides each first-hop link's capacity by the number of
    dosed pairs using it as a first hop. Without the sharing split an
    all-to-all workload double-counts every shared link (two pairs each
    dosing the same 400G chord at its full capacity oversubscribes the
    network at nominal "30% load"); with it, a single-pair run reduces
    to the classic ``N x min(cap)`` convention unchanged."""
    pair_ids = np.asarray(pair_ids, np.int32)
    use: dict = {}         # first-hop link -> number of dosed pairs on it
    per_pair = []          # per pair: {first-hop link: bottleneck cap}
    for pid in pair_ids:
        links = {}
        for k in range(int(table.pair_ncand[pid])):
            p = int(table.pair_cand[pid, k])
            links[int(table.path_first[p])] = int(table.path_cap[p])
        if not links:
            raise ValueError(f"pair {int(pid)} has no installed candidate "
                             "paths")
        per_pair.append(links)
        for li in links:
            use[li] = use.get(li, 0) + 1
    return np.array([len(links) * min(c / use[li]
                                      for li, c in links.items())
                     for links in per_pair], np.float64)


def pair_dose_basis(table: PathTable, pid: int) -> float:
    """Single-pair basis (no sharing): ``N_first_hops x min cap``."""
    return float(dose_bases(table, [pid])[0])


def _fmix32_np(x: np.ndarray) -> np.ndarray:
    """numpy twin of ``core.select.fmix32`` (MurmurHash3 finalizer)."""
    x = x.astype(np.uint32)
    x = x ^ (x >> np.uint32(16))
    x = x * np.uint32(0x85EBCA6B)
    x = x ^ (x >> np.uint32(13))
    x = x * np.uint32(0xC2B2AE35)
    x = x ^ (x >> np.uint32(16))
    return x


def _split_subflows(arrivals, sizes, pids, fids, fg, k: int):
    """AMP-style multi-subflow expansion: each parent flow becomes ``k``
    subflows of ``size/k`` arriving together, each with its own
    deterministic hash key derived from the parent id (distinct keys are
    what makes the subflows route independently under hash-based
    policies). Returns the expanded arrays plus the ``subflow_of``
    row -> parent map metrics use to score the parent at last-subflow
    completion. Runs AFTER the rng draw sequence is complete, so the
    ``n_subflows=1`` path stays bit-for-bit identical to legacy output."""
    n = len(arrivals)
    rep = lambda a: np.repeat(a, k)
    sub_k = np.tile(np.arange(k, dtype=np.uint32), n)
    sub_fid = _fmix32_np(rep(fids) ^ (sub_k * np.uint32(0x9E3779B9)))
    sub_fid = np.where(sub_fid == 0, np.uint32(1), sub_fid)  # ids stay nonzero
    return (rep(arrivals), rep(sizes) / k, rep(pids), sub_fid, rep(fg),
            np.repeat(np.arange(n, dtype=np.int32), k))


def _poisson_window(rng: np.random.Generator, lam: float,
                    duration_us: int) -> np.ndarray:
    """Arrival times of one Poisson process covering the FULL window.

    Draws ``1.2x expected + 64`` exponential gaps up front and tops up
    until the cumulative sum passes ``duration_us`` — the window is
    covered by construction, never silently cut short."""
    n = int(lam * duration_us * 1.2) + 64
    arr = np.cumsum(rng.exponential(1.0 / lam, n))
    while arr[-1] < duration_us:          # top-up (vanishingly rare)
        more = rng.exponential(1.0 / lam, max(n // 4, 64))
        arr = np.concatenate([arr, arr[-1] + np.cumsum(more)])
    return arr[arr < duration_us * 1e0]


def generate(table: PathTable, cdf: SizeCDF, load: float, duration_us: int,
             pair_ids=None, seed: int = 0, max_flows: int = 200_000,
             cap_scale: float = 1.0, bg_pair_ids=None,
             bg_load: float = 0.0, n_subflows: int = 1) -> FlowSet:
    """Poisson arrivals at per-pair utilization ``load`` over
    ``duration_us`` (plus optional ``bg_load`` cross-traffic on
    ``bg_pair_ids``).

    ``cap_scale`` must match the simulator's capacity scale so the
    offered byte rate targets the *simulated* capacities. Raises
    ``ValueError`` when the requested load needs more than ``max_flows``
    flows — the pre-fix behavior silently cut the *end* of the arrival
    window instead, simulating less offered load than requested.
    """
    rng = np.random.default_rng(seed)
    if pair_ids is None:
        pair_ids = np.arange(len(table.pair_src))
    pair_ids = np.asarray(pair_ids, np.int32)
    bg_pair_ids = (np.zeros(0, np.int32) if bg_pair_ids is None or bg_load <= 0
                   else np.asarray(bg_pair_ids, np.int32))
    bg_pair_ids = bg_pair_ids[~np.isin(bg_pair_ids, pair_ids)]

    mean_size = cdf.mean()
    doses = [(int(p), float(load), True) for p in pair_ids] + \
            [(int(p), float(bg_load), False) for p in bg_pair_ids]
    # first-hop sharing is split WITHIN each dose group: the foreground
    # pairs divide capacity among themselves (all-to-all stays sane) but
    # keep their full class against the background set — cross-traffic is
    # the interference being measured, not a reason to dose the measured
    # pair less
    bases = np.concatenate([
        dose_bases(table, pair_ids),
        dose_bases(table, bg_pair_ids) if len(bg_pair_ids) else np.zeros(0)])
    lams = {p: ld * base * 125.0 * cap_scale / mean_size
            for (p, ld, _), base in zip(doses, bases)}  # flows/us per pair

    expect = (sum(int(lams[p] * duration_us * 1.2) + 64 for p, _, _ in doses)
              * max(int(n_subflows), 1))
    if expect > max_flows:
        raise ValueError(
            f"offered load needs ~{expect} flows but max_flows={max_flows}: "
            f"the arrival window would be silently truncated (under-dosed). "
            f"Raise max_flows (>= {expect}) or chunk the run into shorter "
            f"duration_us segments.")

    if len(doses) == 1 and doses[0][2]:
        # single foreground pair: keep the exact legacy draw sequence
        # (gaps -> sizes -> pair assignment -> ids from one rng stream) so
        # every pre-existing single-pair experiment, tolerance band, and
        # tuned acceptance test stays bit-for-bit reproducible.
        pid = doses[0][0]
        arrivals = _poisson_window(rng, lams[pid], duration_us)
        n = len(arrivals)
        sizes = cdf.sample(rng, n)
        pids = pair_ids[rng.integers(0, len(pair_ids), n)]
        fids = rng.integers(1, 1 << 32, n, dtype=np.uint32)
        fg = np.ones(n, bool)
        dose_real = np.array([sizes.sum() / duration_us])
    else:
        chunks = []
        for p, ld, is_fg in doses:
            arr = _poisson_window(rng, lams[p], duration_us)
            chunks.append((p, is_fg, arr, cdf.sample(rng, len(arr))))
        # realized byte-rates straight off the per-pair chunks (no
        # per-flow remapping of the merged table needed)
        dose_real = np.array([s.sum() / duration_us
                              for _, _, _, s in chunks])
        arrivals = np.concatenate([a for _, _, a, _ in chunks])
        sizes = np.concatenate([s for _, _, _, s in chunks])
        pids = np.concatenate([np.full(len(a), p, np.int32)
                               for p, _, a, _ in chunks])
        fg = np.concatenate([np.full(len(a), is_fg)
                             for _, is_fg, a, _ in chunks])
        order = np.argsort(arrivals, kind="stable")
        arrivals, sizes, pids, fg = (arrivals[order], sizes[order],
                                     pids[order], fg[order])
        fids = rng.integers(1, 1 << 32, len(arrivals), dtype=np.uint32)

    dose_pair = np.array([p for p, _, _ in doses], np.int32)
    dose_target = np.array(
        [lams[p] * mean_size for p, _, _ in doses], np.float64)

    # amp-style subflow expansion — after dose telemetry (byte rates are
    # a parent-level property, preserved exactly by the equal split) and
    # after every rng draw (the legacy draw sequence stays untouched)
    subflow_of = None
    if n_subflows > 1:
        (arrivals, sizes, pids, fids, fg,
         subflow_of) = _split_subflows(arrivals, sizes, pids, fids, fg,
                                       int(n_subflows))

    return FlowSet(arrival_us=arrivals.astype(np.int64),
                   size_bytes=sizes, pair_id=pids.astype(np.int32),
                   flow_id=fids, fg_mask=fg, subflow_of=subflow_of,
                   dose_pair=dose_pair, dose_target=dose_target,
                   dose_real=dose_real)
