"""Per-pair piecewise-constant load schedules (``ExpSpec.load_sched``).

The paper's evaluation holds offered load fixed per run; real inter-DC
traffic is dominated by the diurnal cycle — each DC's demand follows
local time (timezone ~= longitude / 15 deg per hour), weighted by the
population it serves, punctured by flash crowds and occasional
traffic-matrix shifts. This module builds the ``(sched_t, load_rows,
bg_rows)`` arrays ``traffic.gen.generate`` consumes, from a wire string
with the same grammar as the scenario registry::

    ExpSpec(load_sched="diurnal:amp=0.8,segs=24")
    ExpSpec(load_sched="diurnal:flash_at_ms=150,flash_dur_ms=30,flash_mult=3")
    ExpSpec(load_sched="flash:at_ms=100,dur_ms=20,mult=4")
    ExpSpec(load_sched="const:segs=8")     # == scalar load, bit-for-bit

Rows are load *multipliers* with time-average ~1 per pair (population
weights are normalized to mean 1 within each dose group), so
``ExpSpec.load`` keeps its meaning as the pair's time-average
utilization. The string is a **dynamic** sweep axis: schedules only
reshape the flow tables, never ``SimConfig``, so sweep cells with
different schedules batch into one compiled trace per engine.

Families (``FAMILIES`` is wire format, pinned by the registry test):

- ``const``  : all-ones rows over ``segs`` segments. Exercises the
  schedule plumbing while reproducing the legacy scalar draw sequence
  bit-for-bit (constant rows take the homogeneous path in gen).
- ``diurnal``: ``w_p * (1 + amp * cos(2 pi * (local_p(t) - peak_h/24)))``
  sampled at segment midpoints, where ``local_p(t) = t/day + lon_src/360``
  is the source DC's local time fraction (one compressed 24 h cycle per
  ``day_ms``, default the run duration) and ``w_p`` the population
  weight ``pop_src * pop_dst`` (mean-1 normalized per group; scenarios
  without ``dc_pop``/``dc_lon`` run unweighted at phase 0). Optional
  flash crowd (``flash_at_ms``/``flash_dur_ms``/``flash_mult``, on all
  pairs or only those sourced at DC ``flash_src``) and a mid-run
  traffic-matrix shift (``shift_ms``: the population-weight assignment
  reverses across each group — demand migrates between metros).
- ``flash``  : flat rows with only the flash-crowd window — the
  isolated burst case (``at_ms``/``dur_ms``/``mult``/``src``).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.netsim import scenarios as scenmod

FAMILIES: Tuple[str, ...] = ("const", "diurnal", "flash")


def _grid(duration_us: int, segs: int) -> np.ndarray:
    """(K,) int64 segment start times: K equal segments over the run."""
    segs = max(int(segs), 1)
    return (np.arange(segs, dtype=np.int64) * int(duration_us)) // segs


def _mids(sched_t: np.ndarray, duration_us: int) -> np.ndarray:
    """(K,) float64 segment midpoints (where shapes are sampled)."""
    ends = np.append(sched_t[1:], duration_us).astype(np.float64)
    return (sched_t + ends) / 2.0


def _weights(table, scen, pids) -> np.ndarray:
    """Mean-1 population weights ``pop_src * pop_dst`` for one dose
    group (all-ones when the scenario carries no ``dc_pop``)."""
    pids = np.asarray(pids, np.int64)
    if scen is None or scen.dc_pop is None or len(pids) == 0:
        return np.ones(len(pids), np.float64)
    pop = np.asarray(scen.dc_pop, np.float64)
    w = (pop[np.asarray(table.pair_src)[pids]]
         * pop[np.asarray(table.pair_dst)[pids]])
    return w / w.mean()


def _src_lon_frac(table, scen, pids) -> np.ndarray:
    """Per-pair timezone phase: source DC longitude as a fraction of the
    day (lon / 15 deg-per-hour / 24 h = lon / 360). Zero without
    ``dc_lon`` metadata."""
    pids = np.asarray(pids, np.int64)
    if scen is None or scen.dc_lon is None or len(pids) == 0:
        return np.zeros(len(pids), np.float64)
    lon = np.asarray(scen.dc_lon, np.float64)
    return lon[np.asarray(table.pair_src)[pids]] / 360.0


def _group_rows(table, scen, pids, sched_t, duration_us, *, amp, day_us,
                peak_frac, weighted, flash_at, flash_dur, flash_mult,
                flash_src, shift_at) -> np.ndarray:
    """(P, K) multiplier rows for one dose group."""
    pids = np.asarray(pids, np.int64)
    mids = _mids(sched_t, duration_us)
    w = (_weights(table, scen, pids) if weighted
         else np.ones(len(pids), np.float64))
    phase = _src_lon_frac(table, scen, pids)
    local = mids[None, :] / day_us + phase[:, None]
    shape = 1.0 + amp * np.cos(2.0 * np.pi * (local - peak_frac))
    rows = w[:, None] * shape
    if shift_at >= 0:
        # traffic-matrix shift: the weight assignment reverses across
        # the group from shift_at on (metro demand migrates)
        rows = np.where(mids[None, :] >= shift_at,
                        w[::-1][:, None] * shape, rows)
    if flash_at >= 0 and flash_dur > 0 and flash_mult != 1.0:
        seg_in = (mids >= flash_at) & (mids < flash_at + flash_dur)
        if flash_src >= 0:
            pair_in = np.asarray(table.pair_src)[pids] == flash_src
        else:
            pair_in = np.ones(len(pids), bool)
        rows = rows * np.where(pair_in[:, None] & seg_in[None, :],
                               float(flash_mult), 1.0)
    return np.clip(rows, 0.0, None)


def _const(duration_us, table, scen, fg_ids, bg_ids, segs: int = 4):
    t = _grid(duration_us, segs)
    return (t, np.ones((len(fg_ids), len(t))), np.ones((len(bg_ids), len(t))))


def _diurnal(duration_us, table, scen, fg_ids, bg_ids, amp: float = 0.8,
             day_ms: int = 0, segs: int = 24, peak_h: float = 20.0,
             weighted: int = 1, flash_at_ms: int = -1,
             flash_dur_ms: int = 0, flash_mult: float = 3.0,
             flash_src: int = -1, shift_ms: int = -1):
    if not 0.0 <= float(amp) < 1.0:
        raise ValueError(f"diurnal amp must be in [0, 1), got {amp}")
    t = _grid(duration_us, segs)
    day_us = float(int(day_ms) * 1000 if int(day_ms) > 0 else duration_us)
    kw = dict(amp=float(amp), day_us=day_us,
              peak_frac=float(peak_h) / 24.0, weighted=int(weighted),
              flash_at=float(flash_at_ms) * 1000.0,
              flash_dur=float(flash_dur_ms) * 1000.0,
              flash_mult=float(flash_mult), flash_src=int(flash_src),
              shift_at=float(shift_ms) * 1000.0)
    return (t, _group_rows(table, scen, fg_ids, t, duration_us, **kw),
            _group_rows(table, scen, bg_ids, t, duration_us, **kw))


def _flash(duration_us, table, scen, fg_ids, bg_ids, at_ms: int = 0,
           dur_ms: int = 0, mult: float = 3.0, src: int = -1,
           segs: int = 24, weighted: int = 0):
    if int(dur_ms) <= 0:
        raise ValueError("flash needs dur_ms > 0")
    t = _grid(duration_us, segs)
    kw = dict(amp=0.0, day_us=float(duration_us), peak_frac=0.0,
              weighted=int(weighted), flash_at=float(at_ms) * 1000.0,
              flash_dur=float(dur_ms) * 1000.0, flash_mult=float(mult),
              flash_src=int(src), shift_at=-1.0)
    return (t, _group_rows(table, scen, fg_ids, t, duration_us, **kw),
            _group_rows(table, scen, bg_ids, t, duration_us, **kw))


_BUILDERS = {"const": _const, "diurnal": _diurnal, "flash": _flash}
assert tuple(sorted(_BUILDERS)) == tuple(sorted(FAMILIES))


def build(spec: str, duration_us: int, table, scen=None,
          fg_ids=(), bg_ids=()) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Resolve a schedule string to ``(sched_t (K,), fg_rows (P_fg, K),
    bg_rows (P_bg, K))`` multiplier arrays for ``gen.generate``."""
    name, params = scenmod.parse(spec)
    if name not in _BUILDERS:
        raise ValueError(f"unknown load schedule {name!r}; "
                         f"available: {', '.join(FAMILIES)}")
    try:
        return _BUILDERS[name](int(duration_us), table, scen,
                               list(fg_ids), list(bg_ids), **params)
    except TypeError as e:
        raise ValueError(f"bad parameters for load schedule {name!r}: "
                         f"{e}") from e
