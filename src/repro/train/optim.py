"""Optimizers (pure JAX, pytree-structured states, sharded like params)."""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jnp.ndarray
    mu: dict
    nu: dict


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


def adamw_init(params) -> AdamWState:
    z = jax.tree.map(jnp.zeros_like, params)
    return AdamWState(count=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree.map(jnp.zeros_like, params))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def clip_by_global_norm(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState):
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state.count + 1
    lr = _schedule(cfg, count.astype(jnp.float32))
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        step = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        return p - lr * step, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(count=count, mu=new_m, nu=new_v), gnorm
