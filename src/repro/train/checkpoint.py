"""Sharded, atomic, topology-free checkpointing with auto-resume.

Design (runnability at 1000+ nodes):
- each host writes only the *addressable* shards of each array to its own
  ``shard-<host>.npz`` (no cross-host traffic at save time);
- a tiny JSON manifest records the tree structure, global shapes, dtypes
  and the logical PartitionSpecs — NOT device ids — so a checkpoint can be
  restored onto a *different* mesh (elastic re-shard: restore reads the
  global array and re-shards under the new mesh's NamedSharding);
- writes are atomic (tmp dir + rename); a partial save never shadows the
  last good step; ``latest()`` resumes from the newest complete manifest.

On this single-process CPU container the host count is 1; the layout and
code paths are identical multi-host (jax.process_index() keys the shard
files).
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flat(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return [("/".join(str(k) for k in path), leaf) for path, leaf in leaves], \
        jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, tree: Any, specs: Any = None) -> str:
    """Atomic save of a pytree (params/opt/anything) at ``step``."""
    final = os.path.join(ckpt_dir, f"step-{step:08d}")
    tmp = final + f".tmp-{jax.process_index()}"
    os.makedirs(tmp, exist_ok=True)

    items, _ = _flat(tree)
    arrays = {}
    manifest = {"step": step, "leaves": {}}
    for name, leaf in items:
        arr = np.asarray(jax.device_get(leaf))
        arrays[name.replace("/", "__")] = arr
        manifest["leaves"][name] = dict(shape=list(arr.shape),
                                        dtype=str(arr.dtype))
    if specs is not None:
        sitems, _ = _flat(specs)
        manifest["specs"] = {n: str(s) for n, s in sitems}
    np.savez(os.path.join(tmp, f"shard-{jax.process_index()}.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(os.path.join(tmp, "manifest.json"),
               os.path.join(tmp, "MANIFEST.json"))  # completeness marker
    if os.path.isdir(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


_STEP_DIR = re.compile(r"^step-(\d{8})$")


def latest(ckpt_dir: str) -> Optional[Tuple[int, str]]:
    """Newest complete checkpoint (auto-resume entry point).

    Only exact ``step-<8 digits>`` names count: an interrupted save
    leaves a ``step-XXXXXXXX.tmp-<host>`` dir behind (possibly with a
    MANIFEST inside) and must never be picked up or crash the scan."""
    if not os.path.isdir(ckpt_dir):
        return None
    best = None
    for d in sorted(os.listdir(ckpt_dir)):
        m = _STEP_DIR.match(d)
        full = os.path.join(ckpt_dir, d)
        if m and os.path.exists(os.path.join(full, "MANIFEST.json")):
            best = (int(m.group(1)), full)
    return best


def restore(path: str, like: Any, mesh=None, specs: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``mesh``+``specs`` are
    given, each array is placed with the *new* mesh's NamedSharding —
    this is the elastic re-shard path (checkpoint saved on mesh A,
    restored on mesh B)."""
    from jax.sharding import NamedSharding

    data = np.load(os.path.join(path, "shard-0.npz"))
    items, treedef = _flat(like)
    out = []
    spec_items = _flat(specs)[0] if specs is not None else None
    for i, (name, leaf) in enumerate(items):
        arr = data[name.replace("/", "__")]
        if mesh is not None and spec_items is not None:
            arr = jax.device_put(arr, NamedSharding(mesh, spec_items[i][1]))
        else:
            arr = jnp.asarray(arr)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)
