"""train_step / serve_step builders — the functions the launcher jits with
mesh shardings and the dry-run lowers.

Compute flows: params f32 (sharded FSDPxTP), activations bf16, grads f32,
AdamW f32. Cross-pod gradient reduction goes through the LCMP-scheduled
collective layer (repro.dist.lcmp_collectives) when a 'pod' axis exists;
optionally int8-compressed (repro.dist.compress).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.arch import ArchConfig, forward, init_params
from repro.serve.decode import decode_step
from repro.train.optim import AdamWConfig, AdamWState, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optim: AdamWConfig = AdamWConfig()
    microbatches: int = 1            # gradient accumulation
    pod_reduce: str = "psum"         # psum | lcmp | lcmp_int8
    pod_axis: Optional[str] = None   # set to "pod" on multi-pod meshes


def loss_fn(params, cfg: ArchConfig, tokens, labels, extra=None):
    logits = forward(params, cfg, tokens, extra=extra)
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = labels >= 0
    nll = jnp.where(mask, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def make_train_step(cfg: ArchConfig, tcfg: TrainConfig = TrainConfig()):
    """Returns train_step(params, opt, batch) -> (params, opt, metrics)."""

    def grads_of(params, tokens, labels, extra):
        return jax.value_and_grad(loss_fn)(params, cfg, tokens, labels,
                                           extra=extra)

    def train_step(params, opt: AdamWState, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        extra = batch.get("extra")
        mb = tcfg.microbatches
        if mb > 1:
            B = tokens.shape[0]
            tk = tokens.reshape(mb, B // mb, -1)
            lb = labels.reshape(mb, B // mb, -1)
            ex = (extra.reshape(mb, B // mb, *extra.shape[1:])
                  if extra is not None else None)

            def acc(carry, xs):
                gsum, lsum = carry
                t, l = xs[0], xs[1]
                e = xs[2] if len(xs) > 2 else None
                loss, g = grads_of(params, t, l, e)
                return (jax.tree.map(jnp.add, gsum, g), lsum + loss), None

            zero = jax.tree.map(lambda p: jnp.zeros_like(p), params)
            xs = (tk, lb) if ex is None else (tk, lb, ex)
            (gsum, lsum), _ = jax.lax.scan(acc, (zero, jnp.zeros(())), xs)
            grads = jax.tree.map(lambda g: g / mb, gsum)
            loss = lsum / mb
        else:
            loss, grads = grads_of(params, tokens, labels, extra)

        # cross-pod gradient reduction (the paper's technique lives here)
        if tcfg.pod_axis is not None:
            from repro.dist import lcmp_collectives as lc
            if tcfg.pod_reduce == "psum":
                grads = jax.tree.map(
                    lambda g: jax.lax.pmean(g, tcfg.pod_axis), grads)
            elif tcfg.pod_reduce == "lcmp":
                grads = lc.lcmp_pod_reduce(grads, tcfg.pod_axis,
                                           compress=False)
            elif tcfg.pod_reduce == "lcmp_int8":
                grads = lc.lcmp_pod_reduce(grads, tcfg.pod_axis,
                                           compress=True)

        params2, opt2, gnorm = adamw_update(tcfg.optim, params, grads, opt)
        return params2, opt2, dict(loss=loss, grad_norm=gnorm)

    return train_step


def make_serve_step(cfg: ArchConfig):
    """Returns serve_step(params, cache, tokens, pos) -> (logits, cache)."""

    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    return serve_step


def init_train_state(cfg: ArchConfig, key):
    params = init_params(cfg, key)
    return params, adamw_init(params)
