"""Unit + property tests for the on-switch congestion estimator (§3.3).

Queue depths are passed in 1 KiB cells (see tables.py unit note)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import cong, tables

TB = tables.bootstrap_tables([100, 100, 400], buffer_bytes=6 * 10**9)
P = cong.CongParams()
GB_CELLS = 10**9 // 1024  # cells in 1 GB


def _state(n=3):
    return cong.CongState.init(n)


def _cells(*bytes_):
    return jnp.asarray([b // 1024 for b in bytes_], jnp.int32)


def test_empty_queues_zero_cost():
    s = _state()
    s = cong.monitor_update(s, jnp.zeros(3, jnp.int32), 0, TB, P)
    assert np.asarray(cong.calc_cong_cost(s, TB, P)).tolist() == [0, 0, 0]


def test_q_signal_monotone_in_queue_depth():
    s = _state()
    s = cong.monitor_update(s, _cells(0, 3 * 10**9, 6 * 10**9), 0, TB, P)
    q, _, _ = cong.cong_signals(s, TB, P)
    q = np.asarray(q)
    assert q[0] <= q[1] <= q[2] and q[0] < q[2]


def test_trend_positive_on_growth_zero_on_drain():
    s = _state()
    s = cong.monitor_update(s, _cells(0, 10**9, 10**9), 0, TB, P)
    s = cong.monitor_update(s, _cells(0, 2 * 10**9, 0), 100, TB, P)
    _, t, _ = cong.cong_signals(s, TB, P)
    t = np.asarray(t)
    assert t[0] == 0          # never had bytes
    assert t[1] > 0           # growing queue
    assert t[2] == 0          # draining queue -> non-positive trend clamps to 0


def test_ewma_shift_matches_eq3():
    s = _state(1)
    k = P.ewma_k
    t_acc = 0
    qprev = 0
    for step, qc in enumerate([1000, 5000, 3000, 3000, 20000]):
        s = cong.monitor_update(s, jnp.array([qc], jnp.int32), step * 100, TB, P)
        delta = qc - qprev
        t_acc = t_acc - (t_acc >> k) + (delta >> k)
        qprev = qc
        assert int(s.trend[0]) == t_acc  # bit-exact Eq. (3)


def test_duration_counter_arms_and_decays():
    s = _state(1)
    full = _cells(6 * 10**9)
    for i in range(8):
        s = cong.monitor_update(s, full, i * 100, TB, P)
    assert int(s.dur_cnt[0]) == 8
    for i in range(3):
        s = cong.monitor_update(s, _cells(0), 800 + i * 100, TB, P)
    assert int(s.dur_cnt[0]) == 1  # halved thrice


@settings(max_examples=150, deadline=None)
@given(st.lists(st.integers(0, 6 * GB_CELLS), min_size=1, max_size=12))
def test_cong_cost_always_in_byte_range(qs):
    s = _state(1)
    for i, qc in enumerate(qs):
        s = cong.monitor_update(s, jnp.array([qc], jnp.int32), i * 100, TB, P)
        c = int(cong.calc_cong_cost(s, TB, P)[0])
        assert 0 <= c <= 255


def test_persistent_congestion_scores_higher_than_burst():
    """A queue that *stays* high must out-score a one-sample burst of the
    same depth (the D persistence term at work)."""
    burst = _state(1)
    burst = cong.monitor_update(burst, _cells(5 * 10**9), 0, TB, P)

    persist = _state(1)
    for i in range(40):
        persist = cong.monitor_update(persist, _cells(5 * 10**9), i * 100, TB, P)
    cb = int(cong.calc_cong_cost(burst, TB, P)[0])
    cp = int(cong.calc_cong_cost(persist, TB, P)[0])
    assert cp > cb


def test_trend_normalization_rate_dependent():
    """Same byte growth is a *stronger* signal on a slower link."""
    tb = tables.bootstrap_tables([25, 400], buffer_bytes=6 * 10**9)
    s = cong.CongState.init(2)
    grow = _cells(2 * 10**8, 2 * 10**8)
    s = cong.monitor_update(s, grow // 2, 0, tb, P)
    s = cong.monitor_update(s, grow, 100, tb, P)
    _, t, _ = cong.cong_signals(s, tb, P)
    assert int(t[0]) >= int(t[1])
