"""Tests for fused cost + diversity-preserving selection (§3.4) and the
herd-mitigation property the paper designs for."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import select


def test_fused_cost_eq1_defaults():
    p = select.SelectParams()
    c = select.fused_cost(jnp.array([10]), jnp.array([20]), p)
    assert int(c[0]) == 3 * 10 + 1 * 20


def test_selects_only_valid_candidates():
    fids = jnp.arange(64, dtype=jnp.uint32)
    c_path = jnp.array([5, 5, 5, 5])
    c_cong = jnp.zeros(4, jnp.int32)
    valid = jnp.array([True, False, True, False])
    idx, _ = select.select_egress(fids, c_path, c_cong, valid)
    assert set(np.asarray(idx).tolist()) <= {0, 2}


def test_low_cost_half_only():
    """Stage-1 filter: no flow may land on the high-cost suffix."""
    fids = jnp.arange(256, dtype=jnp.uint32)
    c_path = jnp.array([0, 10, 200, 250])     # clear cost split
    c_cong = jnp.zeros(4, jnp.int32)
    idx, _ = select.select_egress(fids, c_path, c_cong, jnp.ones(4, bool))
    assert set(np.asarray(idx).tolist()) <= {0, 1}


def test_herd_mitigation_spreads_simultaneous_flows():
    """A burst of simultaneous flows must spread across the low-cost set
    rather than herd onto the single cheapest port."""
    fids = jnp.arange(1000, dtype=jnp.uint32) * jnp.uint32(2654435761)
    c_path = jnp.array([10, 12, 200, 220, 240, 250])
    c_cong = jnp.zeros(6, jnp.int32)
    idx, _ = select.select_egress(fids, c_path, c_cong, jnp.ones(6, bool))
    counts = np.bincount(np.asarray(idx), minlength=6)
    # low-cost set = {0,1,2} (keep ceil(6/2)); each should carry ~1/3
    assert counts[3:].sum() == 0
    assert counts[:3].min() > 1000 / 3 * 0.5   # no herd: reasonably even
    assert counts[:3].max() < 1000 / 3 * 1.5


def test_fallback_argmin_when_all_congested():
    fids = jnp.arange(128, dtype=jnp.uint32)
    c_path = jnp.array([50, 10, 30])
    c_cong = jnp.array([240, 250, 235])       # all >= fallback bar (230)
    idx, _ = select.select_egress(fids, c_path, c_cong, jnp.ones(3, bool))
    # argmin fused: 3*50+240=390, 3*10+250=280, 3*30+235=325 -> idx 1
    assert (np.asarray(idx) == 1).all()


def test_no_valid_candidates_returns_minus_one():
    fids = jnp.arange(4, dtype=jnp.uint32)
    idx, _ = select.select_egress(fids, jnp.zeros(3), jnp.zeros(3),
                                  jnp.zeros(3, bool))
    assert (np.asarray(idx) == -1).all()


def test_selection_deterministic_per_flow():
    fids = jnp.array([7, 7, 7, 7], dtype=jnp.uint32)
    c_path = jnp.array([1, 2, 3, 4, 5, 6])
    idx, _ = select.select_egress(fids, c_path, jnp.zeros(6, jnp.int32),
                                  jnp.ones(6, bool))
    assert len(set(np.asarray(idx).tolist())) == 1  # same flow -> same path


@settings(max_examples=200, deadline=None)
@given(
    st.integers(1, 8),
    st.lists(st.integers(0, 255), min_size=8, max_size=8),
    st.lists(st.integers(0, 255), min_size=8, max_size=8),
    st.integers(0, 2**32 - 1),
)
def test_property_choice_always_valid_and_low_half(m, cps, ccs, fid):
    """For any cost vector, the choice is a valid candidate inside the
    lower-cost half (or the argmin under global-congestion fallback)."""
    valid = jnp.arange(8) < m
    c_path = jnp.array(cps, jnp.int32)
    c_cong = jnp.array(ccs, jnp.int32)
    idx, cost = select.select_egress(jnp.array([fid], dtype=jnp.uint32),
                                     c_path, c_cong, valid)
    i = int(idx[0])
    assert 0 <= i < m
    # chosen cost must be <= median of the valid fused costs
    fused = np.asarray(cost[0])[:m]
    keep = max(1, (m + 1) // 2)
    kth = np.sort(fused)[keep - 1]
    assert fused[i] <= kth


def test_ecmp_uniform_over_valid():
    fids = jnp.arange(3000, dtype=jnp.uint32) * jnp.uint32(40503)
    valid = jnp.array([True, True, False, True])
    idx = select.ecmp_select(fids, valid)
    counts = np.bincount(np.asarray(idx), minlength=4)
    assert counts[2] == 0
    assert counts[[0, 1, 3]].min() > 3000 / 3 * 0.7
