"""Seeded physics bugs, one per sanitizer invariant.

Each entry is a ``(t, state) -> state`` corruptor installed on
``repro.netsim.sanitize._MUTATION``. The sanitizer applies it at the top
of ``step_check`` and the corrupted state flows onward through the scan
— exactly how a real engine bug would propagate — so a passing
``tests/test_sanitize.py`` proves every invariant actually fires, on
both engines, from inside the jitted checkify program.

Two invariants have no step-state corruptor here: ``signal_causality``
is seeded by corrupting ``SimArrays.path_sig_delay`` before the run, and
``pfc_lossless`` by patching the ``sanitize.pfc_gate`` seam to ignore
the pause signal (see test_sanitize.py).
"""
import dataclasses

import jax.numpy as jnp


def _queue_nonneg(t, st):
    return dataclasses.replace(st, q_bytes=st.q_bytes - 1.0)


def _buffer_bound(t, st):
    return dataclasses.replace(st, q_bytes=st.q_bytes + 1e12)


def _byte_conservation(t, st):
    return dataclasses.replace(
        st, remaining=jnp.where(st.flow_path >= 0,
                                st.remaining + 1e9, st.remaining))


def _ring_head(t, st):
    return dataclasses.replace(st, hist_q=st.hist_q + 1.0)


def _clock_monotone(t, st):
    return dataclasses.replace(
        st, route_step=jnp.where(st.flow_path >= 0,
                                 t + 10, st.route_step))


def _cc_rate_bounds(t, st):
    return dataclasses.replace(st, rate=jnp.where(st.active, -1.0, st.rate))


def _cong_quantized(t, st):
    return dataclasses.replace(st, c_path=jnp.full_like(st.c_path, 999))


def _completion_identity(t, st):
    return dataclasses.replace(st, done=st.done | st.active)


MUTATIONS = {
    "queue_nonneg": _queue_nonneg,
    "buffer_bound": _buffer_bound,
    "byte_conservation": _byte_conservation,
    "ring_head": _ring_head,
    "clock_monotone": _clock_monotone,
    "cc_rate_bounds": _cc_rate_bounds,
    "cong_quantized": _cong_quantized,
    "completion_identity": _completion_identity,
}
