"""Traffic-plane schedule contracts: the per-pair piecewise load
schedule (``traffic.sched`` + ``gen._poisson_sched``) realizes its
time-integral within Poisson tolerance (property-tested), a constant
schedule reproduces the legacy scalar-load rng draw sequence
**bit-for-bit** (FlowSet level for every registered scenario, engine
level for both backends, and against the pinned single-pair numbers),
schedules batch as a dynamic sweep axis, and the diurnal/flash shapes
follow their geography (timezone phase from source longitude, flash
windows, traffic-matrix shifts)."""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.netsim import scenarios, sweep
from repro.netsim.experiment import (ExpSpec, background_pair_ids,
                                     build_world, make_flows,
                                     traffic_pair_ids)
from repro.traffic import cdf as cdfmod
from repro.traffic import sched
from repro.traffic.gen import generate, pair_dose_basis

WS = cdfmod.WORKLOADS["websearch"]


def _main_pid(topology):
    scen, table = build_world(topology)
    return scen, table, table.pair_index()[scen.main_pair]


# ---------------------------------------------- integral-tracking property
@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=3.0),
                min_size=2, max_size=8),
       st.integers(min_value=0, max_value=9))
def test_realized_rate_tracks_schedule_integral(mults, seed):
    """For an arbitrary non-negative multiplier row, the realized
    arrival count per segment is Poisson(lam_k * seg_dur_k) — within
    normal-approximation tolerance per segment AND in aggregate. This is
    the property that catches thinning bugs (wrong segment lookup,
    biased accept draws) regardless of the schedule's shape."""
    dur = 400_000
    scen, table, main = _main_pid("testbed8")
    K = len(mults)
    sched_t = (np.arange(K, dtype=np.int64) * dur) // K
    rows = np.array([mults], np.float64)
    fs = generate(table, WS, 0.3, dur, pair_ids=[main], seed=seed,
                  cap_scale=0.125, sched_t=sched_t, load_rows=rows)
    # lam per segment from the generator's own telemetry: dose_target is
    # the time-average byte-rate, so lam_k = mult_k * lam_unit
    seg_dur = np.diff(np.append(sched_t, dur)).astype(np.float64)
    avg_mult = float((rows[0] * seg_dur).sum()) / dur
    basis = pair_dose_basis(table, main)        # 6 x 40G on testbed8
    assert np.isclose(fs.dose_target[0],
                      avg_mult * 0.3 * basis * 125.0 * 0.125)
    lam_unit = 0.3 * basis * 125.0 * 0.125 / WS.mean()
    seg = np.searchsorted(sched_t, fs.arrival_us, side="right") - 1
    for k in range(K):
        expect = mults[k] * lam_unit * seg_dur[k]
        got = int((seg == k).sum())
        # 6-sigma normal band around the Poisson mean (+5 floors the
        # band so near-zero segments admit their rare stragglers)
        assert abs(got - expect) <= 6.0 * np.sqrt(expect) + 5.0, \
            (k, got, expect)
    # byte-rate telemetry: realized tracks the schedule time-integral
    # (heavy-tailed sizes => distribution-level bound, as elsewhere)
    n, e = fs.num_flows, avg_mult * lam_unit * dur
    assert abs(n - e) <= 6.0 * np.sqrt(e) + 5.0
    if fs.dose_target[0] > 0:
        assert np.isclose(fs.dose_real[0],
                          fs.size_bytes.sum() / dur)


def test_all_zero_schedule_draws_nothing():
    scen, table, main = _main_pid("testbed8")
    fs = generate(table, WS, 0.3, 100_000, pair_ids=[main], seed=0,
                  cap_scale=0.125, sched_t=np.array([0, 50_000]),
                  load_rows=np.zeros((1, 2)))
    assert fs.num_flows == 0 and fs.dose_target[0] == 0.0


# ------------------------------------------ constant == scalar, bit-for-bit
def test_single_pair_const_schedule_matches_pinned_sequence():
    """The pre-PR pinned draw sequence (test_wan_large pins the scalar
    path) must fall out of the schedule path too: a constant row takes
    the legacy homogeneous branch with ZERO extra rng draws."""
    scen, table, main = _main_pid("testbed8")
    K = 6
    sched_t = (np.arange(K, dtype=np.int64) * 300_000) // K
    fs = generate(table, WS, 0.3, 300_000, pair_ids=[main], seed=0,
                  cap_scale=0.125, sched_t=sched_t,
                  load_rows=np.ones((1, K)))
    assert fs.num_flows == 1389
    assert fs.arrival_us[:3].tolist() == [142, 356, 360]
    assert fs.flow_id[:3].tolist() == [2132099435, 1045437217, 929310042]


def _flowsets_equal(a, b):
    assert np.array_equal(a.arrival_us, b.arrival_us)
    assert np.array_equal(a.size_bytes, b.size_bytes)
    assert np.array_equal(a.pair_id, b.pair_id)
    assert np.array_equal(a.flow_id, b.flow_id)
    assert np.array_equal(a.foreground, b.foreground)
    assert np.allclose(a.dose_target, b.dose_target)
    assert np.allclose(a.dose_real, b.dose_real)


@pytest.mark.parametrize("name", scenarios.names())
def test_const_schedule_is_bitwise_legacy_every_scenario(name):
    """`load_sched="const"` == no schedule at all, for every registered
    scenario, foreground-only AND with background cross-traffic (the
    multi-pair path where constant rows must bypass thinning)."""
    base = ExpSpec(topology=name, load=0.25, duration_us=60_000, seed=3,
                   cap_scale=0.0625)
    scen, table = build_world(name)
    for bg in (0.0, 0.1):
        legacy = dataclasses.replace(base, bg_load=bg)
        scheduled = dataclasses.replace(base, bg_load=bg,
                                        load_sched="const:segs=5")
        _flowsets_equal(make_flows(legacy, scen, table),
                        make_flows(scheduled, scen, table))


@pytest.mark.parametrize("engine", ["fluid", "packet"])
def test_const_schedule_engine_run_bit_identical(engine):
    """Full-run equality per engine: the schedule axis must not perturb
    a single simulated byte when the schedule is flat."""
    specs = [ExpSpec(topology="testbed8", load=0.3, duration_us=50_000,
                     seed=1, engine=engine, bg_load=0.05,
                     load_sched=ls)
             for ls in ("", "const:segs=4")]
    rep = sweep.run_sweep(specs, sequential=True)
    a, b = rep.results
    assert np.array_equal(np.asarray(a.final.fct_us),
                          np.asarray(b.final.fct_us))
    assert np.array_equal(np.asarray(a.final.done),
                          np.asarray(b.final.done))


def test_sweep_load_sched_axis_bit_for_bit():
    """load_sched is a dynamic axis: a grid mixing schedules (and none)
    shares one compiled trace per scenario and reproduces the
    sequential loop exactly."""
    mk = lambda ls, pol: ExpSpec(topology="testbed8", load=0.3,
                                 duration_us=60_000, seed=2, policy=pol,
                                 bg_load=0.08, load_sched=ls)
    specs = [mk(ls, pol)
             for ls in ("", "const:segs=4", "diurnal:amp=0.6,segs=8",
                        "flash:at_ms=10,dur_ms=15,mult=3")
             for pol in ("lcmp", "ecmp")]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    assert bat.num_cells == len(specs)
    assert bat.num_groups == 1          # one trace for the whole grid
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.done, b.final.done), b.spec
        assert np.array_equal(a.stats.slowdown, b.stats.slowdown), b.spec


# --------------------------------------------------- shape semantics (geo)
GEO8 = "geo:dcs=8,chords=4"


def _geo_rows(spec_str, **kw):
    scen, table = build_world(GEO8)
    spec = ExpSpec(topology=GEO8, **kw)
    fg = traffic_pair_ids(spec, scen, table)
    bg = background_pair_ids(table, fg)
    t, fg_rows, bg_rows = sched.build(spec_str, 240_000, table, scen,
                                      fg, bg)
    return scen, table, fg, bg, t, fg_rows, bg_rows


def test_diurnal_phase_shifts_with_source_longitude():
    """Each pair's diurnal peak lands at its source DC's local peak
    hour: pairs sourced at different longitudes peak in different
    segments, offset by lon/360 of the day."""
    scen, table, fg, bg, t, fg_rows, bg_rows = _geo_rows(
        "diurnal:amp=0.8,segs=24,weighted=0", pairs="all")
    dur = 240_000
    mids = (t + np.append(t[1:], dur)) / 2.0
    src = np.asarray(table.pair_src)[np.asarray(fg)]
    lon = np.asarray(scen.dc_lon, np.float64)
    expect = 1.0 + 0.8 * np.cos(2.0 * np.pi * (
        mids[None, :] / dur + lon[src, None] / 360.0 - 20.0 / 24.0))
    assert np.allclose(fg_rows, expect)
    # two sources ~opposite longitudes peak in anti-phase
    i = int(np.argmin(lon[src]))
    j = int(np.argmax(lon[src]))
    dlon = (lon[src[j]] - lon[src[i]]) / 360.0
    shift = (np.argmax(fg_rows[j]) - np.argmax(fg_rows[i])) % 24
    assert abs(shift - (-dlon * 24) % 24) <= 1.0
    # time-average stays ~1 (load keeps its meaning under the cycle)
    assert np.allclose(fg_rows.mean(axis=1), 1.0, atol=0.01)


def test_diurnal_population_weights_and_shift():
    """Weighted rows scale by mean-1-normalized pop_src*pop_dst; a
    traffic-matrix shift reverses the weight assignment mid-run."""
    scen, table, fg, bg, t, fg_rows, _ = _geo_rows(
        "diurnal:amp=0.5,segs=12,weighted=1", pairs="all")
    pop = np.asarray(scen.dc_pop, np.float64)
    src = np.asarray(table.pair_src)[np.asarray(fg)]
    dst = np.asarray(table.pair_dst)[np.asarray(fg)]
    w = pop[src] * pop[dst]
    w = w / w.mean()
    _, _, _, _, t0, flat, _ = _geo_rows(
        "diurnal:amp=0.5,segs=12,weighted=0", pairs="all")
    assert np.allclose(fg_rows, w[:, None] * flat)
    # shift_ms: first half keeps w, second half uses reversed w
    _, _, _, _, _, sh, _ = _geo_rows(
        "diurnal:amp=0.5,segs=12,weighted=1,shift_ms=120", pairs="all")
    mids = (t + np.append(t[1:], 240_000)) / 2.0
    pre, post = mids < 120_000, mids >= 120_000
    assert np.allclose(sh[:, pre], fg_rows[:, pre])
    assert np.allclose(sh[:, post], (w[::-1, None] * flat)[:, post])


def test_flash_window_and_src_filter():
    """flash multiplies only the segments whose midpoints fall in the
    window, and only pairs sourced at `src` when given."""
    scen, table, fg, bg, t, rows, bg_rows = _geo_rows(
        "flash:at_ms=60,dur_ms=60,mult=4", pairs="all")
    mids = (t + np.append(t[1:], 240_000)) / 2.0
    inwin = (mids >= 60_000) & (mids < 120_000)
    assert inwin.any() and (~inwin).any()
    assert np.allclose(rows[:, inwin], 4.0)
    assert np.allclose(rows[:, ~inwin], 1.0)
    assert np.allclose(bg_rows[:, inwin], 4.0)      # bg flashes too
    src_dc = int(np.asarray(table.pair_src)[fg[0]])
    _, _, _, _, _, rows_src, _ = _geo_rows(
        f"flash:at_ms=60,dur_ms=60,mult=4,src={src_dc}", pairs="all")
    hit = np.asarray(table.pair_src)[np.asarray(fg)] == src_dc
    assert hit.any() and (~hit).any()
    assert np.allclose(rows_src[hit][:, inwin], 4.0)
    assert np.allclose(rows_src[~hit], 1.0)


# ------------------------------------------------------------- validation
def test_schedule_string_errors():
    scen, table = build_world("testbed8")
    with pytest.raises(ValueError, match="unknown load schedule"):
        sched.build("sawtooth:amp=1", 1000, table, scen, [0], [])
    with pytest.raises(ValueError, match="bad parameters"):
        sched.build("diurnal:bogus=3", 1000, table, scen, [0], [])
    with pytest.raises(ValueError, match="amp"):
        sched.build("diurnal:amp=1.5", 1000, table, scen, [0], [])
    with pytest.raises(ValueError, match="dur_ms"):
        sched.build("flash:at_ms=10", 1000, table, scen, [0], [])


def test_generate_validates_schedule_arrays():
    scen, table, main = _main_pid("testbed8")
    with pytest.raises(ValueError, match="ascending"):
        generate(table, WS, 0.3, 10_000, pair_ids=[main],
                 sched_t=np.array([5, 10]), load_rows=np.ones((1, 2)))
    with pytest.raises(ValueError, match="rows must be"):
        generate(table, WS, 0.3, 10_000, pair_ids=[main],
                 sched_t=np.array([0, 5000]), load_rows=np.ones((2, 2)))
    with pytest.raises(ValueError, match="non-negative"):
        generate(table, WS, 0.3, 10_000, pair_ids=[main],
                 sched_t=np.array([0, 5000]),
                 load_rows=np.array([[1.0, -0.5]]))
