"""Per-kernel allclose validation against the pure-jnp oracles (ref.py),
swept over shapes and dtypes, in Pallas interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cong import CongParams, CongState
from repro.core.select import SelectParams
from repro.core.tables import bootstrap_tables
from repro.kernels import ops, ref


# ---------------------------------------------------------------- lcmp_decide
@pytest.mark.parametrize("F", [1, 7, 128, 300, 1024])
@pytest.mark.parametrize("P", [2, 3, 6, 8])
def test_lcmp_decide_matches_ref_shapes(F, P):
    k = jax.random.key(F * 17 + P)
    k1, k2, k3, k4 = jax.random.split(k, 4)
    fids = jax.random.randint(k1, (F,), 0, 1 << 30).astype(jnp.uint32)
    c_path = jax.random.randint(k2, (F, P), 0, 256).astype(jnp.int32)
    c_cong = jax.random.randint(k3, (F, P), 0, 256).astype(jnp.int32)
    valid = jax.random.bernoulli(k4, 0.8, (F, P))
    got = ops.lcmp_decide(fids, c_path, c_cong, valid)
    want = ref.lcmp_decide_ref(fids, c_path, c_cong, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", range(4))
def test_lcmp_decide_matches_ref_param_sweep(seed):
    params = [SelectParams(alpha=1, beta=1), SelectParams(alpha=1, beta=3),
              SelectParams(alpha=3, beta=1, cong_fallback=100),
              SelectParams(alpha=2, beta=2, keep_num=3)][seed]
    k = jax.random.key(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    F, P = 256, 6
    fids = jax.random.randint(k1, (F,), 0, 1 << 30).astype(jnp.uint32)
    c_path = jax.random.randint(k2, (F, P), 0, 256).astype(jnp.int32)
    c_cong = jax.random.randint(k3, (F, P), 0, 256).astype(jnp.int32)
    valid = jnp.ones((F, P), bool)
    got = ops.lcmp_decide(fids, c_path, c_cong, valid, params)
    want = ref.lcmp_decide_ref(fids, c_path, c_cong, valid, params)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_lcmp_decide_all_invalid_rows():
    F, P = 130, 4
    fids = jnp.arange(F, dtype=jnp.uint32)
    z = jnp.zeros((F, P), jnp.int32)
    valid = jnp.zeros((F, P), bool).at[0].set(True)
    got = ops.lcmp_decide(fids, z, z, valid)
    want = ref.lcmp_decide_ref(fids, z, z, valid)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert (np.asarray(got)[1:] == -1).all()


# ---------------------------------------------------------------- cong_update
@pytest.mark.parametrize("n_ports", [1, 5, 128, 400])
def test_cong_update_matches_ref(n_ports):
    tb = bootstrap_tables([100] * n_ports, buffer_bytes=6 * 10**9)
    st = CongState.init(n_ports)
    k = jax.random.key(n_ports)
    for step in range(4):
        k, sub = jax.random.split(k)
        q = jax.random.randint(sub, (n_ports,), 0, 5 * 10**6).astype(jnp.int32)
        st_k, cc_k = ops.cong_update(st, q, step * 100, tb)
        st_r, cc_r = ref.cong_update_ref(st, q, step * 100, tb)
        np.testing.assert_array_equal(np.asarray(cc_k), np.asarray(cc_r))
        for f in ("queue_cur", "queue_prev", "trend", "dur_cnt"):
            np.testing.assert_array_equal(np.asarray(getattr(st_k, f)),
                                          np.asarray(getattr(st_r, f)), err_msg=f)
        st = st_r


def test_cong_update_param_sweep():
    tb = bootstrap_tables([25, 100, 400], buffer_bytes=10**9)
    p = CongParams(w_ql=1, w_tl=2, w_dp=1, ewma_k=2, dur_shift=1)
    st = CongState.init(3)
    q = jnp.array([10**5, 5 * 10**5, 9 * 10**5], jnp.int32)
    st_k, cc_k = ops.cong_update(st, q, 100, tb, p)
    st_r, cc_r = ref.cong_update_ref(st, q, 100, tb, p)
    np.testing.assert_array_equal(np.asarray(cc_k), np.asarray(cc_r))


# ------------------------------------------------------------------- qsr_int8
@pytest.mark.parametrize("n", [1024, 4096, 64 * 1024])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_qsr_int8_matches_ref(n, dtype):
    k1, k2 = jax.random.split(jax.random.key(n))
    x = (jax.random.normal(k1, (n,), jnp.float32) * 3).astype(dtype).astype(jnp.float32)
    bits = jax.random.bits(k2, (n,), jnp.uint32)
    qk, sk = ops.qsr_int8(x, bits)
    qr, sr = ref.qsr_int8_ref(x, bits)
    # float contract: XLA may fuse x*(127/amax) differently between the two
    # programs, so floor() ties can flip by one step on ~1e-5 of elements;
    # everything else must match exactly.
    dq = np.abs(np.asarray(qk, np.int32) - np.asarray(qr, np.int32))
    assert dq.max() <= 1
    assert (dq != 0).mean() < 1e-4
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)
    # roundtrip error bounded by one quantization step per element
    xr = ops.qsr_dequant(qk, sk)
    step = np.repeat(np.asarray(sr), 1024)
    assert (np.abs(np.asarray(xr - x)) <= step + 1e-7).all()


def test_qsr_int8_zero_block_and_unbiasedness():
    n = 2048
    x = jnp.zeros((n,), jnp.float32).at[1024:].set(0.3)
    reps = 64
    acc = np.zeros(n)
    for s in range(reps):
        bits = jax.random.bits(jax.random.key(s), (n,), jnp.uint32)
        q, sc = ops.qsr_int8(x, bits)
        acc += np.asarray(ops.qsr_dequant(q, sc))
    acc /= reps
    assert (acc[:1024] == 0).all()                       # zero block stays zero
    np.testing.assert_allclose(acc[1024:], 0.3, atol=2e-3)  # SR is unbiased
