"""Multi-engine contracts: the packet engine hits closed-form FCT when
uncongested, agrees with the fluid engine within stated tolerance bands
on the quick testbed, and both engines drive the *same* routing path
through the degenerate candidate cases (one valid slot, all-invalid,
weighted-hash bounds). Plus the Engine protocol/registry itself."""
import dataclasses

import numpy as np
import pytest

from repro.netsim import engine as enginemod
from repro.netsim import fluid, packet, paths, topo
from repro.netsim.engine import Engine, SimConfig, attach_link_caps
from repro.netsim.experiment import ExpSpec, build_experiment, run_experiment
from repro.traffic.gen import FlowSet


# ------------------------------------------------------------ registry
def test_engine_registry_and_protocol():
    for name in enginemod.ENGINES:
        eng = enginemod.get_engine(name)
        assert eng.name == name
        assert isinstance(eng, Engine)          # build/run_impl/run present
    with pytest.raises(ValueError, match="fluid"):
        enginemod.get_engine("ns3")             # error names the valid set


def test_spec_engine_threads_into_config():
    from repro.netsim.experiment import spec_to_cfg
    from repro.netsim import scenarios
    scen = scenarios.get("testbed8")
    assert spec_to_cfg(ExpSpec(engine="packet"), scen).engine == "packet"
    assert spec_to_cfg(ExpSpec(), scen).engine == "fluid"


# --------------------------------------------- closed-form single flow
def _single_flow_world(size, cap=100, delay=5000, arrival=1000):
    t = topo.parallel_paths(caps=(cap,), delays_us=(delay,))
    table = paths.build_path_table(t, [(0, 2)])
    attach_link_caps(table, t)
    flows = FlowSet(arrival_us=np.array([arrival], np.int64),
                    size_bytes=np.array([float(size)]),
                    pair_id=np.array([0], np.int32),
                    flow_id=np.array([42], np.uint32))
    return table, flows


@pytest.mark.parametrize("policy", ["lcmp", "ecmp"])
@pytest.mark.parametrize("size", [5e6, 1e5])
def test_packet_single_flow_matches_closed_form(policy, size):
    """A flow alone in the network: the packet engine's measured FCT must
    equal ``prop + size / bottleneck_cap`` within one slot (slot
    quantization is the engine's only discretization error here — pacing
    injects whole MTU packets at line rate and the idle path cuts
    through within the slot)."""
    table, flows = _single_flow_world(size)
    cfg = SimConfig(engine="packet", policy=policy, horizon_us=200_000,
                    cap_scale=1.0)
    arrs, st = packet.build(table, flows, cfg)
    final = packet.run(arrs, st, cfg)
    assert bool(final.done[0])
    ideal = 6000.0 + size / (100 * 125.0)   # prop(5ms+1ms tail) + serialize
    got = float(final.fct_us[0])
    assert abs(got - ideal) <= cfg.dt_us + 1e-3, (got, ideal)
    # lossless delivery: every byte of the flow arrived, exactly once
    assert abs(float(final.delivered[0]) - size) < 1.0


def test_packet_queues_lossless_and_buffer_bounded():
    """Silent 99% degradation of a single-route world with the PFC
    thresholds tightened (the configurable-knob path): XOFF must engage
    on the degraded link, the queue must stay inside the (scaled) buffer
    at every recorded step — pause plus the space bound, never drops —
    and in-flight bytes must remain non-negative."""
    spec = ExpSpec(topology="parallel:n=1,cap=100", load=0.5, policy="ecmp",
                   engine="packet", duration_us=100_000, seed=3)
    _, table, flows, cfg = build_experiment(spec)
    first = int(table.path_first[0])
    cfg = dataclasses.replace(cfg, degrade_sched=((first, 20_000, 0.01),),
                              pfc_xoff_frac=0.02, pfc_xon_frac=0.01)
    arrs, st = packet.build(table, flows, cfg)
    final = packet.run(arrs, st, cfg)
    buf = cfg.buffer_bytes * cfg.cap_scale
    assert float(np.asarray(final.hist_q).max()) <= buf + 1e-3
    assert float(np.asarray(final.fq).min()) >= -1e-3
    # the degraded link's pause state engaged at some point in the run...
    # reprolint: ignore[RNG001] link-axis index over the whole ring
    assert np.asarray(final.hist_pause)[first].any()
    # ...and the queue peak stayed near the XOFF line, far below the
    # buffer (pause is doing the limiting, not the space clamp)
    # reprolint: ignore[RNG001] link-axis index over the whole ring
    peak = float(np.asarray(final.hist_q)[first].max())
    assert peak < 0.5 * buf


# ------------------------------------------------- cross-engine parity
def test_engines_parity_quick_testbed8():
    """Stated tolerance bands on the quick 8-DC testbed at 30% load:
    oblivious policies (placement-dominated FCT) agree on p50 within
    10%; the congestion-reactive lcmp — where the engines' queue models
    legitimately differ (analytic wait estimates vs experienced queueing)
    — within a factor of 2. The paper's headline ordering (LCMP below
    ECMP on median AND tail) must hold under both backends."""
    st = {}
    for pol in ("lcmp", "ecmp"):
        for eng in ("fluid", "packet"):
            stats, _, _ = run_experiment(ExpSpec(
                topology="testbed8", load=0.3, policy=pol, engine=eng,
                duration_us=300_000, seed=1))
            assert stats.completed / stats.offered > 0.95
            st[(pol, eng)] = stats
    f, p = st[("ecmp", "fluid")], st[("ecmp", "packet")]
    assert abs(p.p50 - f.p50) / f.p50 < 0.10, (f.p50, p.p50)
    f, p = st[("lcmp", "fluid")], st[("lcmp", "packet")]
    assert 0.5 < p.p50 / f.p50 < 2.0, (f.p50, p.p50)
    for eng in ("fluid", "packet"):
        assert st[("lcmp", eng)].p50 < st[("ecmp", eng)].p50, eng
        assert st[("lcmp", eng)].p99 < st[("ecmp", eng)].p99, eng


# ------------------------------- degenerate candidates, both engines
def _burst_world(topology, n_flows=64, size=2e4):
    """A same-slot burst (the herd case) against a named scenario world:
    every decision is made at t=0 on identical all-zero congestion
    state, so the two engines' shared routing path must produce
    *identical* placements."""
    from repro.netsim import scenarios
    scen = scenarios.get(topology)
    t = scen.topology
    pair_list = paths.all_pairs(t)
    table = paths.build_path_table(t, pair_list)
    attach_link_caps(table, t)
    pidx = table.pair_index()[scen.main_pair]
    rng = np.random.default_rng(0)
    flows = FlowSet(
        arrival_us=np.zeros(n_flows, np.int64),
        size_bytes=np.full(n_flows, float(size)),
        pair_id=np.full(n_flows, pidx, np.int32),
        flow_id=rng.integers(1, 1 << 32, n_flows, dtype=np.uint32))
    return table, flows, pidx


def _both_engines(table, flows, **cfg_kw):
    out = {}
    for eng_name in ("fluid", "packet"):
        eng = enginemod.get_engine(eng_name)
        cfg = SimConfig(engine=eng_name, horizon_us=100_000, **cfg_kw)
        arrs, st = eng.build(table, flows, cfg)
        out[eng_name] = (np.asarray(eng.run(arrs, st, cfg).flow_path), arrs)
    return out


def test_single_valid_candidate_identical():
    """One candidate slot: every flow lands on it, in both engines."""
    table, flows, _ = _burst_world("parallel:n=1")
    res = _both_engines(table, flows, policy="lcmp")
    for fp, _ in res.values():
        assert (fp == fp[0]).all() and fp[0] >= 0
    assert np.array_equal(res["fluid"][0], res["packet"][0])


def test_all_candidates_invalid_identical():
    """Every candidate dead at arrival: select reports -1, no flow ever
    activates or completes — identically in both engines."""
    table, flows, _ = _burst_world("parallel:n=2")
    firsts = sorted({int(f) for f in table.path_first})
    res = {}
    for eng_name in ("fluid", "packet"):
        eng = enginemod.get_engine(eng_name)
        cfg = SimConfig(engine=eng_name, policy="lcmp", horizon_us=50_000,
                        fail_sched=tuple((li, 0) for li in firsts))
        arrs, st = eng.build(table, flows, cfg)
        final = eng.run(arrs, st, cfg)
        assert (np.asarray(final.flow_path) == -1).all()
        assert not np.asarray(final.done).any()
        res[eng_name] = np.asarray(final.flow_path)
    assert np.array_equal(res["fluid"], res["packet"])


def test_weighted_hash_bounds_identical():
    """lcmp_w (capacity-weighted stage-2 hash) on heterogeneous parallel
    routes: choices stay inside the pair's candidate set, the kept
    (lowest-cost) prefix is actually load-shared, and the same-slot herd
    places identically under both engines."""
    table, flows, pidx = _burst_world(
        "longhaul_mesh:routes=4,segs=1,caps=200+100+40,hi_ms=5")
    res = _both_engines(table, flows, policy="lcmp_w")
    cands = set(table.pair_cand[pidx][:table.pair_ncand[pidx]].tolist())
    for fp, _ in res.values():
        assert set(fp.tolist()) <= cands          # never out of bounds
        assert (fp >= 0).all()
        assert len(set(fp.tolist())) >= 2         # hash spreads the herd
    assert np.array_equal(res["fluid"][0], res["packet"][0])


def test_select_egress_weighted_hash_degenerate_slots():
    """Unit-level weighted-hash bounds: a single valid slot always wins
    regardless of weights; zero/extreme weights never index outside the
    kept prefix."""
    import jax.numpy as jnp
    from repro.core.select import select_egress
    fid = jnp.asarray((np.arange(128, dtype=np.uint64) * 2654435761)
                      % (1 << 32), jnp.uint32)
    c_path = jnp.asarray([10, 20, 30, 40], jnp.int32)
    c_cong = jnp.zeros(4, jnp.int32)
    only1 = jnp.asarray([False, True, False, False])
    w_extreme = jnp.asarray([1, 1 << 20, 0, 1], jnp.int32)
    choice, _ = select_egress(fid, c_path, c_cong, only1, weights=w_extreme)
    assert (np.asarray(choice) == 1).all()
    allv = jnp.ones(4, bool)
    choice, _ = select_egress(fid, c_path, c_cong, allv, weights=w_extreme)
    got = np.asarray(choice)
    assert ((got >= 0) & (got < 4)).all()
    # keep = ceil(4/2) = 2 -> only the two cheapest slots are eligible
    assert set(got.tolist()) <= {0, 1}


# ------------------------------------------------- sweep x engine axis
def test_sweep_engine_axis_groups_and_matches_sequential():
    """engine is a static (trace-level) sweep axis: a mixed fluid+packet
    grid forms one group per engine, and the batched packet results are
    bit-for-bit equal to the sequential per-cell loop."""
    from repro.netsim import sweep
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol, engine=eng,
                     duration_us=60_000, seed=1)
             for eng in ("fluid", "packet") for pol in ("lcmp", "ecmp")]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    assert bat.num_groups == 2
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.done, b.final.done), b.spec
        assert np.array_equal(a.util, b.util), b.spec


def test_packet_failover_completes_and_avoids_dead_link():
    """Packet-engine lazy failover: stranded queued bytes are returned to
    the source (go-back-N), flows re-hash onto live candidates and still
    complete; nothing re-lands on the dead link."""
    spec = ExpSpec(topology="testbed8_failover:fail_ms=60,link=12",
                   load=0.3, policy="lcmp", engine="packet",
                   duration_us=180_000, seed=5)
    stats, _, (_, table, flows, cfg, final) = run_experiment(spec)
    done = np.asarray(final.done)
    assert done.mean() > 0.95
    path_links = np.asarray(table.path_links)
    uses12 = (path_links == 12).any(-1)[np.maximum(np.asarray(final.flow_path),
                                                   0)]
    late = done & (flows.arrival_us > 60_000)
    assert not uses12[late].any()
