"""Runtime physics-invariant sanitizer (repro.netsim.sanitize).

Three contracts:
1. every seeded physics bug in the mutation corpus is caught, on both
   engines, by the invariant that owns it (checkify reports the first
   failing check, so the match also pins check ordering);
2. the checked program computes the *same physics*: checks-on output is
   bit-for-bit identical to checks-off (the sanitizer only observes);
3. the knobs work — ``ExpSpec.checks``, the ``REPRO_CHECKS`` env
   override, and the host-side accounting checks in ``metrics``.
"""
import dataclasses

import jax
import pytest
from jax.experimental import checkify

from mutations import MUTATIONS
from repro.netsim import experiment, fluid, metrics, packet, sanitize

SPEC = dict(topology="testbed8", load=0.7, duration_us=40_000)
ENGINES = {"fluid": fluid, "packet": packet}


def _build(engine_name, checks=True, **cfg_over):
    spec = experiment.ExpSpec(engine=engine_name, checks=int(checks), **SPEC)
    _, table, flows, cfg = experiment.build_experiment(spec)
    if cfg_over:
        cfg = dataclasses.replace(cfg, **cfg_over)
    mod = ENGINES[engine_name]
    arrs, st = mod.build(table, flows, cfg)
    return mod, arrs, st, cfg


@pytest.fixture(autouse=True)
def _fresh_checked_cache():
    # the checked runner caches jit(checkify(run_impl)) per cfg; a
    # mutation is baked into that trace, so tests must not share it
    sanitize._checked_runner.cache_clear()
    yield
    sanitize._checked_runner.cache_clear()


# ------------------------------------------------------ mutation corpus
def test_mutation_corpus_covers_every_invariant():
    # signal_causality/pfc_lossless are seeded via SimArrays / the
    # pfc_gate seam below rather than a step-state corruptor
    assert (set(MUTATIONS) | {"signal_causality", "pfc_lossless"}
            == set(sanitize.INVARIANTS))


@pytest.mark.parametrize("engine_name", sorted(ENGINES))
@pytest.mark.parametrize("name", sorted(MUTATIONS))
def test_seeded_bug_is_caught(engine_name, name, monkeypatch):
    mod, arrs, st, cfg = _build(engine_name)
    monkeypatch.setattr(sanitize, "_MUTATION", MUTATIONS[name])
    with pytest.raises(checkify.JaxRuntimeError, match=name):
        mod.run(arrs, st, cfg)


@pytest.mark.parametrize("engine_name", sorted(ENGINES))
def test_signal_causality_caught(engine_name):
    mod, arrs, st, cfg = _build(engine_name)
    bad = dataclasses.replace(arrs,
                              path_sig_delay=-(arrs.path_sig_delay + 1))
    with pytest.raises(checkify.JaxRuntimeError, match="signal_causality"):
        mod.run(bad, st, cfg)


def test_pfc_gate_break_is_caught(monkeypatch):
    # all-pairs traffic into a buffer small enough that PFC pauses
    # actually fire on downstream hops at this load
    spec = experiment.ExpSpec(engine="packet", pairs="all", checks=1,
                              **SPEC)
    _, table, flows, cfg = experiment.build_experiment(spec)
    cfg = dataclasses.replace(cfg, buffer_bytes=2e5)
    mod = ENGINES["packet"]
    arrs, st = mod.build(table, flows, cfg)
    # honored gate: pauses occur, nothing is forwarded into them
    mod.run(arrs, st, cfg)
    # broken gate (ignores the pause signal): check_pfc must fire
    monkeypatch.setattr(sanitize, "pfc_gate", lambda okh, paused: okh)
    sanitize._checked_runner.cache_clear()
    with pytest.raises(checkify.JaxRuntimeError, match="pfc_lossless"):
        mod.run(arrs, st, cfg)


# ------------------------------------------------- observation-only runs
@pytest.mark.parametrize("engine_name", sorted(ENGINES))
def test_checked_run_is_bit_identical(engine_name):
    """The sanitizer only observes: the checks-on final state equals the
    checks-off final state bit for bit, so debug mode can never change
    a paper number."""
    mod, arrs, st, cfg_on = _build(engine_name, checks=True)
    cfg_off = dataclasses.replace(cfg_on, checks=False)
    a = mod.run(arrs, st, cfg_off)
    b = mod.run(arrs, st, cfg_on)
    la = jax.tree.leaves(dataclasses.asdict(a))
    lb = jax.tree.leaves(dataclasses.asdict(b))
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert (x == y).all(), "sanitizer perturbed simulation state"


# ---------------------------------------------------------------- knobs
def test_spec_checks_flag_reaches_cfg():
    spec = experiment.ExpSpec(**SPEC)
    _, _, _, cfg = experiment.build_experiment(spec)
    assert cfg.checks is False
    _, _, _, cfg = experiment.build_experiment(
        dataclasses.replace(spec, checks=1))
    assert cfg.checks is True


def test_env_override_forces_checks_on(monkeypatch):
    monkeypatch.setenv("REPRO_CHECKS", "1")
    _, _, _, cfg = experiment.build_experiment(experiment.ExpSpec(**SPEC))
    assert cfg.checks is True


def test_host_checks_catch_broken_completion_accounting(monkeypatch):
    spec = experiment.ExpSpec(engine="fluid", **SPEC)
    _, table, flows, cfg = experiment.build_experiment(spec)
    arrs, st = fluid.build(table, flows, cfg)
    final = fluid.run(arrs, st, cfg)
    # a "completed" flow with FCT 0 — the accounting identity is broken
    broken = dataclasses.replace(
        final, done=final.done.at[:].set(True),
        fct_us=final.fct_us.at[:].set(0.0))
    # silent without the env knob (the default production path)...
    metrics.fct_stats(broken, table, flows, cfg)
    # ...and a hard failure with it
    monkeypatch.setenv("REPRO_CHECKS", "1")
    with pytest.raises(AssertionError, match="completion_identity"):
        metrics.fct_stats(broken, table, flows, cfg)
    metrics.fct_stats(final, table, flows, cfg)   # intact state passes
