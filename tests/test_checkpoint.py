"""Checkpoint robustness + launcher auto-resume coverage."""
import sys

import jax
import numpy as np
import pytest

from repro import configs
from repro.train import checkpoint as ckpt
from repro.train.step import init_train_state


def test_latest_ignores_interrupted_tmp_dirs(tmp_path):
    """Regression: a leftover ``step-XXXXXXXX.tmp-<host>`` dir from an
    interrupted save (which can contain a MANIFEST) used to crash
    ``latest()`` with ValueError on ``int("00000007.tmp")``."""
    d = str(tmp_path)
    tree = {"w": np.arange(6.0).reshape(2, 3)}
    path = ckpt.save(d, 7, tree)

    stale = tmp_path / "step-00000009.tmp-0"
    stale.mkdir()
    (stale / "MANIFEST.json").write_text("{}")
    (tmp_path / "step-garbage").mkdir()
    (tmp_path / "step-00000012").mkdir()          # no MANIFEST: incomplete

    assert ckpt.latest(d) == (7, path)


def test_latest_none_cases(tmp_path):
    assert ckpt.latest(str(tmp_path / "missing")) is None
    assert ckpt.latest(str(tmp_path)) is None


def test_save_restore_roundtrip(tmp_path):
    tree = {"a": np.arange(8.0), "b": {"c": np.ones((3,), np.int32)}}
    path = ckpt.save(str(tmp_path), 3, tree)
    out = ckpt.restore(path, tree)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _run_train(argv, monkeypatch):
    from repro.launch.train import main
    monkeypatch.setattr(sys, "argv", ["train"] + argv)
    main()


@pytest.mark.parametrize("arch", ["qwen3_4b"])
def test_train_resume_restores_params_and_opt(tmp_path, monkeypatch, capsys,
                                              arch):
    """--resume must pick up the latest checkpoint once and restore the
    optimizer state alongside the params (the dead-conditional resume
    path used to restore params only)."""
    d = str(tmp_path / "ck")
    common = ["--arch", arch, "--smoke", "--batch", "2", "--seq", "16",
              "--ckpt", d, "--ckpt-every", "2", "--log-every", "10"]
    _run_train(common + ["--steps", "2"], monkeypatch)
    found = ckpt.latest(d)
    assert found and found[0] == 2

    # the checkpoint carries the optimizer: count must equal the step
    cfg = configs.get(arch, smoke=True)
    params, opt = init_train_state(cfg, jax.random.key(0))
    saved = ckpt.restore(found[1], {"params": params, "opt": opt})
    assert int(saved["opt"].count) == 2
    assert any(float(np.abs(np.asarray(m)).sum()) > 0
               for m in jax.tree.leaves(saved["opt"].mu))

    capsys.readouterr()
    _run_train(common + ["--steps", "4", "--resume"], monkeypatch)
    out = capsys.readouterr().out
    assert f"[resume] step 2 from {found[1]}" in out
    found2 = ckpt.latest(d)
    assert found2 and found2[0] == 4
    saved2 = ckpt.restore(found2[1], {"params": params, "opt": opt})
    assert int(saved2["opt"].count) == 4          # optimizer kept counting
