"""Distribution-layer correctness, run in a subprocess with 8 host
devices (the test process itself must keep the default 1-device jax, per
the dry-run isolation rule — XLA device count locks at first init)."""
import json
import os
import subprocess
import sys


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.data.synth import batch_at
from repro.dist.mesh_rules import Rules
from repro.models.arch import init_params
from repro.train.step import init_train_state, make_train_step
from repro.train import checkpoint as ckpt

results = {}

# ---- sharded train step == single-device train step -----------------
cfg = configs.get("qwen3_4b", smoke=True)
params, opt = init_train_state(cfg, jax.random.key(0))
step_fn = make_train_step(cfg)
batch = batch_at(cfg, 0, batch=4, seq=32, host=0)

p_ref, o_ref, m_ref = jax.jit(step_fn)(params, opt, batch)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rules = Rules(cfg, {"data": 2, "model": 4})
pspecs = rules.param_specs(params)
shard = lambda specs: jax.tree.map(
    lambda s: NamedSharding(mesh, s), specs,
    is_leaf=lambda s: isinstance(s, P))
pshard = shard(pspecs)
ospecs = type(opt)(count=P(), mu=pspecs, nu=pspecs)
params_s = jax.device_put(params, pshard)
opt_s = jax.device_put(opt, shard(ospecs))
bspecs = rules.train_batch_specs(4, 32)
batch_s = {k: jax.device_put(v, NamedSharding(mesh, bspecs[k]))
           for k, v in batch.items()}
with mesh:
    p_sh, o_sh, m_sh = jax.jit(step_fn)(params_s, opt_s, batch_s)

results["loss_match"] = bool(np.allclose(float(m_ref["loss"]),
                                          float(m_sh["loss"]), rtol=2e-3))
diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
         for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh))]
results["max_param_diff"] = max(diffs)
results["params_match"] = max(diffs) < 5e-3

# ---- LCMP pod-reduce == pmean over the pod axis -----------------------
from repro.dist import lcmp_collectives as lc
from jax import shard_map

mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
g = {"a": jnp.arange(32.0).reshape(4, 8), "b": jnp.ones((16,)) * 3}

def red_lcmp(x):
    return lc.lcmp_pod_reduce(x, "pod")

def red_ref(x):
    return jax.tree.map(lambda v: jax.lax.pmean(v, "pod"), x)

sm = lambda f: shard_map(f, mesh=mesh2, in_specs=P("pod"),
                         out_specs=P("pod"), check_vma=False)
gx = {"a": jnp.stack([g["a"], g["a"] * 2]), "b": jnp.stack([g["b"], g["b"] * 5])}
want = jax.jit(sm(red_ref))(gx)
got = jax.jit(sm(red_lcmp))(gx)
results["lcmp_reduce_match"] = all(
    bool(np.allclose(np.asarray(a), np.asarray(b), rtol=1e-5))
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)))

# ---- compressed reduce: 4x fewer wire bytes, bounded error ------------
big = jax.random.normal(jax.random.key(1), (2, 1 << 16))
def red_c(x):
    return lc.lcmp_pod_reduce({"g": x}, "pod", compress=True)["g"]
smc = shard_map(red_c, mesh=mesh2, in_specs=P("pod"), out_specs=P("pod"),
                check_vma=False)
got_c = jax.jit(smc)(big)
want_c = jnp.broadcast_to(big.mean(0), big.shape)
err = float(jnp.max(jnp.abs(got_c - want_c)))
scale = float(jnp.max(jnp.abs(big))) / 127
results["compress_err_ok"] = err <= 2.1 * scale

# ---- checkpoint roundtrip + elastic re-shard --------------------------
import tempfile
with tempfile.TemporaryDirectory() as d:
    path = ckpt.save(d, 7, p_sh, pspecs)
    assert ckpt.latest(d)[0] == 7
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))   # DIFFERENT mesh
    rules_b = Rules(cfg, {"data": 4, "model": 2})
    restored = ckpt.restore(path, p_sh, mesh=mesh_b,
                            specs=rules_b.param_specs(params))
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree.leaves(p_sh), jax.tree.leaves(restored))]
    results["elastic_restore_match"] = max(diffs) == 0.0

print("RESULTS:" + json.dumps(results))
"""


def test_distributed_correctness():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), env=env,
        capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, out.stdout + out.stderr[-2000:]
    res = json.loads(line[0][len("RESULTS:"):])
    assert res["loss_match"], res
    assert res["params_match"], res
    assert res["lcmp_reduce_match"], res
    assert res["compress_err_ok"], res
    assert res["elastic_restore_match"], res
