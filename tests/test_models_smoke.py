"""Per-arch smoke tests: reduced same-family configs, one forward + one
train step + one decode step on CPU; output shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models.arch import forward, init_params
from repro.serve.decode import decode_step, init_cache, prefill_cross_cache
from repro.train.step import init_train_state, make_train_step


def _batch(cfg, B=2, S=32, key=0):
    k = jax.random.key(key)
    tokens = jax.random.randint(k, (B, S), 0, cfg.vocab).astype(jnp.int32)
    batch = dict(tokens=tokens, labels=tokens)
    if cfg.family == "vlm":
        batch["extra"] = jax.random.normal(k, (B, cfg.n_patches, cfg.d_model),
                                           jnp.float32)
    if cfg.family == "encdec":
        batch["extra"] = jax.random.normal(k, (B, cfg.enc_seq, cfg.d_model),
                                           jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get(arch, smoke=True)
    params = init_params(cfg, jax.random.key(0))
    b = _batch(cfg, B=2, S=64)
    logits = forward(params, cfg, b["tokens"], extra=b.get("extra"))
    assert logits.shape == (2, 64, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_loss_decreases_and_finite(arch):
    cfg = configs.get(arch, smoke=True)
    params, opt = init_train_state(cfg, jax.random.key(1))
    step = jax.jit(make_train_step(cfg))
    b = _batch(cfg, B=2, S=32, key=1)
    params, opt, m1 = step(params, opt, b)
    params, opt, m2 = step(params, opt, b)
    l1, l2 = float(m1["loss"]), float(m2["loss"])
    assert np.isfinite(l1) and np.isfinite(l2)
    assert l2 < l1 + 0.1   # same batch twice: loss should not blow up
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step_shapes(arch):
    cfg = configs.get(arch, smoke=True)
    params = init_params(cfg, jax.random.key(2))
    B, Smax = 2, 64
    cache = init_cache(cfg, B, Smax)
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.key(3), (B, cfg.enc_seq,
                                                    cfg.d_model), jnp.float32)
        # encode once, then prefill the cross-attn cache
        from repro.models import layers as L
        from repro.models.arch import _attn_apply, _mlp_apply
        e = enc.astype(cfg.adt)
        def enc_layer(h, lp):
            h = _attn_apply(lp["attn"], h, cfg, causal=False, use_rope=False)
            h = _mlp_apply(lp["mlp"], h)
            return h, None
        e, _ = jax.lax.scan(enc_layer, e, params["enc_layers"])
        enc_out = L.rms_norm(e, params["enc_final_ln"])
        xc = prefill_cross_cache(params, cfg, enc_out)
        cache = dict(cache, cross=xc)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, jnp.int32(0)))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    logits2, cache = jax.jit(
        lambda p, c, t: decode_step(p, cfg, c, t, jnp.int32(1)))(params, cache, tok)
    assert np.isfinite(np.asarray(logits2)).all()


def test_decode_matches_forward_dense():
    """Teacher-forced decode must reproduce the training forward logits
    (KV-cache correctness oracle) for a dense arch."""
    cfg = configs.get("qwen3_4b", smoke=True)
    params = init_params(cfg, jax.random.key(4))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.key(5), (B, S), 0, cfg.vocab)
    ref = forward(params, cfg, tokens)
    cache = init_cache(cfg, B, S)
    outs = []
    for i in range(S):
        lg, cache = decode_step(params, cfg, cache, tokens[:, i:i + 1],
                                jnp.int32(i))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_forward_ssm():
    """Same oracle for the Mamba-1 recurrence."""
    cfg = configs.get("falcon_mamba_7b", smoke=True)
    params = init_params(cfg, jax.random.key(6))
    B, S = 1, 8
    tokens = jax.random.randint(jax.random.key(7), (B, S), 0, cfg.vocab)
    ref = forward(params, cfg, tokens)
    cache = init_cache(cfg, B, S)
    outs = []
    for i in range(S):
        lg, cache = decode_step(params, cfg, cache, tokens[:, i:i + 1],
                                jnp.int32(i))
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)
