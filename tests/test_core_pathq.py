"""Unit + property tests for the path-quality representation (paper §3.2)."""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import pathq, tables


def test_delay_score_saturates_at_255():
    p = pathq.PathQParams()
    assert int(pathq.calc_delay_cost(10**9, p)) == 255


def test_delay_score_zero_for_zero_delay():
    assert int(pathq.calc_delay_cost(0)) == 0


def test_delay_score_shift_semantics():
    p = pathq.PathQParams(d_shift=8)
    # 5 ms one-way (1000 km) -> 5000 >> 8 = 19
    assert int(pathq.calc_delay_cost(5000, p)) == 5000 >> 8
    # 250 ms saturates: 250000 >> 8 = 976 -> 255
    assert int(pathq.calc_delay_cost(250_000, p)) == 255


def test_linkcap_monotone_decreasing_in_capacity():
    th = tables.capacity_class_thresholds(400, 10)
    caps = jnp.array([10, 40, 100, 200, 400])
    scores = pathq.calc_linkcap_cost(caps, th)
    s = np.asarray(scores)
    assert (np.diff(s) <= 0).all(), s
    assert s[0] > s[-1]


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 10**7), st.integers(1, 400))
def test_cpath_bounds_and_dtype(delay_us, cap):
    th = tables.capacity_class_thresholds(400, 10)
    c = pathq.calc_path_quality(jnp.array([delay_us]), jnp.array([cap]), th)
    assert c.dtype == jnp.int32
    assert 0 <= int(c[0]) <= 255


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 10**6), st.integers(0, 10**6), st.integers(1, 400))
def test_cpath_monotone_in_delay(d1, d2, cap):
    """More delay at equal capacity never yields a *smaller* C_path."""
    th = tables.capacity_class_thresholds(400, 10)
    lo, hi = min(d1, d2), max(d1, d2)
    c = pathq.calc_path_quality(jnp.array([lo, hi]), jnp.array([cap, cap]), th)
    assert int(c[0]) <= int(c[1])


def test_path_bottleneck_stats_sum_and_min():
    link_delay = jnp.array([10, 20, 30, 40], jnp.int32)
    link_cap = jnp.array([100, 40, 400, 200], jnp.int32)
    paths = jnp.array([[0, 1, -1], [2, 3, 1]], jnp.int32)
    plen = jnp.array([2, 3], jnp.int32)
    d, c = pathq.path_bottleneck_stats(link_delay, link_cap, paths, plen)
    assert d.tolist() == [30, 90]
    assert c.tolist() == [40, 40]


def test_paper_fig1_ranking():
    """Fig. 1 scenario: 6 paths = {high,med,low} capacity x {low,high} delay.

    With the paper's delay-biased weights (3,1) a low-delay/medium-capacity
    path must beat a high-delay/high-capacity one (the UCMP failure mode)."""
    th = tables.capacity_class_thresholds(400, 10)
    delays = jnp.array([5_000, 250_000, 5_000, 250_000, 5_000, 250_000])
    caps = jnp.array([200, 200, 100, 100, 40, 40])
    c = np.asarray(pathq.calc_path_quality(delays, caps, th))
    # low-delay medium-capacity (idx 2) < high-delay high-capacity (idx 1)
    assert c[2] < c[1]
    # and among equal delay, fatter is no worse
    assert c[0] <= c[2] <= c[4]
