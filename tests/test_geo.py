"""Geo-grounded WAN contracts: great-circle math properties (symmetry,
identity, triangle inequality — via hypothesis, stub-backed when the real
package is absent), the Beijing-Frankfurt ground-truth distance and its
mapping to span delays at ~0.67c, geo_wan generator invariants and
determinism, the geo scenario's metadata plumbing, and the registry pin
that freezes the wire-format names (scenario families, schedule families,
policy codes) sweep cell keys are built from."""
from collections import deque

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.netsim import scenarios, topo
from repro.netsim.engine import POLICY_CODES
from repro.netsim.experiment import build_world
from repro.traffic import sched

# ---------------------------------------------------- geodesic properties
_lat = st.floats(min_value=-90.0, max_value=90.0)
_lon = st.floats(min_value=-180.0, max_value=180.0)

# half Earth's circumference: no two surface points are farther apart
_HALF_CIRCUMFERENCE_KM = np.pi * topo.EARTH_RADIUS_KM


@settings(max_examples=200, deadline=None)
@given(_lat, _lon, _lat, _lon)
def test_geodesic_symmetry_and_bounds(la1, lo1, la2, lo2):
    d_ab = float(topo.geodesic_km(la1, lo1, la2, lo2))
    d_ba = float(topo.geodesic_km(la2, lo2, la1, lo1))
    assert d_ab == d_ba                       # haversine is symmetric
    assert 0.0 <= d_ab <= _HALF_CIRCUMFERENCE_KM + 1e-6


@settings(max_examples=100, deadline=None)
@given(_lat, _lon)
def test_geodesic_self_distance_zero(la, lo):
    assert float(topo.geodesic_km(la, lo, la, lo)) == 0.0


@settings(max_examples=200, deadline=None)
@given(_lat, _lon, _lat, _lon, _lat, _lon)
def test_geodesic_triangle_inequality(la1, lo1, la2, lo2, la3, lo3):
    d_ac = float(topo.geodesic_km(la1, lo1, la3, lo3))
    d_ab = float(topo.geodesic_km(la1, lo1, la2, lo2))
    d_bc = float(topo.geodesic_km(la2, lo2, la3, lo3))
    # float slack: each haversine is exact to ~1e-9 relative
    assert d_ac <= d_ab + d_bc + 1e-6


def _dc(name):
    return next(c for c in topo.GEO_DCS if c[0] == name)


def test_beijing_frankfurt_ground_truth():
    """Beijing-Frankfurt is ~7,800 km great-circle (the ISSUE's anchor);
    the derived one-way delay at ~0.67c lands where the WAN
    rule-of-thumb says (~1 ms per 200 km, i.e. ~39 ms one-way)."""
    _, la1, lo1, _ = _dc("beijing")
    _, la2, lo2, _ = _dc("frankfurt")
    d = float(topo.geodesic_km(la1, lo1, la2, lo2))
    assert abs(d - 7800.0) / 7800.0 < 0.02
    delay = topo.fiber_delay_us(d)
    assert delay == int(round(d / topo.FIBER_KM_PER_US))
    assert 36_000 < delay < 41_000            # ~38.7 ms one-way
    # route stretch scales delay linearly; spans chain in 2000 km classes
    assert topo.fiber_delay_us(d, 1.5) == int(round(1.5 * d / topo.FIBER_KM_PER_US))
    assert topo.geo_spans(d, max_spans=8) == int(np.ceil(d / 2000.0))
    assert topo.geo_spans(d, max_spans=4) == 4       # cap binds
    assert topo.fiber_delay_us(0.0) == 1             # metro floor


def test_fiber_speed_constant_is_two_thirds_c():
    assert np.isclose(topo.FIBER_KM_PER_US, 0.299792458 * 0.67)


# ---------------------------------------------------- geo_wan invariants
def _connected(t: topo.Topology) -> bool:
    adj = {}
    for s, d, _, _ in t.links:
        adj.setdefault(s, []).append(d)
    seen, q = {0}, deque([0])
    while q:
        for nb in adj.get(q.popleft(), []):
            if nb not in seen:
                seen.add(nb)
                q.append(nb)
    return len(seen) == t.num_nodes


@pytest.mark.parametrize("dcs,chords", [(20, 10), (8, 4), (24, 12)])
def test_geo_wan_generator_invariants(dcs, chords):
    w = topo.geo_wan(dcs=dcs, chords=chords, seed=0)
    t = w.topology
    assert _connected(t)
    assert w.dc_nodes == tuple(range(dcs))
    assert len(w.dc_lat) == len(w.dc_lon) == len(w.dc_pop) == dcs
    # ring-ordered by longitude over the dcs most populous metros
    assert list(w.dc_lon) == sorted(w.dc_lon)
    assert set(w.dc_name) == {c[0] for c in topo.GEO_DCS[:dcs]}
    # main pair: the ring edge maximizing the population product
    ma, mb = w.main_pair
    assert (mb - ma) % dcs in (1, dcs - 1)
    ring_prods = [w.dc_pop[i] * w.dc_pop[(i + 1) % dcs] for i in range(dcs)]
    assert w.dc_pop[ma] * w.dc_pop[mb] == max(ring_prods)
    # three parallel main hauls, fattest first; END-TO-END haul delay
    # rises with route stretch (fast-fat / slow-thin) — per-link span
    # delays need not be monotone (longer routes chain MORE spans)
    d_main = topo.geodesic_km(w.dc_lat[ma], w.dc_lon[ma],
                              w.dc_lat[mb], w.dc_lon[mb])
    caps = [t.links[li][2] for li in w.main_haul_links]
    assert tuple(caps) == topo.GEO_MAIN_CAPS
    totals = []
    for stretch, li in zip(topo.GEO_MAIN_STRETCH, w.main_haul_links):
        spans = topo.geo_spans(d_main, stretch, w.max_spans)
        seg = max(topo.fiber_delay_us(d_main, stretch) // spans, 1)
        assert t.links[li][3] == seg
        totals.append(seg * spans)
    assert totals == sorted(totals) and len(set(totals)) == 3
    for _, _, cap, dl in t.links:
        assert cap in topo.WAN_CAP_CLASSES
        assert dl >= 1
    # deterministic under (dcs, chords, seed); seed changes the chords
    again = topo.geo_wan(dcs=dcs, chords=chords, seed=0)
    assert again.topology.links == t.links
    other = topo.geo_wan(dcs=dcs, chords=chords, seed=1)
    assert other.topology.links != t.links


def test_geo_wan_rejects_bad_params():
    with pytest.raises(ValueError, match="4 <= dcs"):
        topo.geo_wan(dcs=3)
    with pytest.raises(ValueError, match="4 <= dcs"):
        topo.geo_wan(dcs=len(topo.GEO_DCS) + 1)
    with pytest.raises(ValueError, match="chords"):
        topo.geo_wan(dcs=4, chords=50)


def test_geo_scenario_metadata_and_schedules():
    """The geo scenario advertises DC pairs only, threads the lat/lon/pop
    metadata the diurnal schedule builder keys on, and its fail/degrade
    schedules hit the fat main haul's first span (both directions for
    degrade) — the wan2000 conventions."""
    scen, table = build_world("geo:dcs=20,chords=10")
    w = topo.geo_wan(dcs=20, chords=10, seed=0)
    assert scen.main_pair == w.main_pair
    assert scen.dc_lat == w.dc_lat and scen.dc_lon == w.dc_lon
    assert scen.dc_pop == w.dc_pop
    assert scen.max_hops == 2 * w.max_spans
    assert all(s < 20 and d < 20 for s, d in scen.traffic_pairs)
    assert (table.pair_ncand >= 2).all()
    m = table.pair_index()[scen.main_pair]
    caps = table.path_cap[table.pair_cand[m, : table.pair_ncand[m]]]
    assert caps.max() >= 200 and caps.min() <= 40
    deg = scenarios.get("geo:dcs=20,chords=10,deg_ms=50,deg_factor=0.3")
    assert deg.degrade_sched == ((w.main_haul_links[0], 50_000, 0.3),
                                 (w.main_haul_links[0] + 1, 50_000, 0.3))
    fail = scenarios.get("geo:dcs=20,chords=10,fail_ms=80")
    assert fail.fail_sched == ((w.main_haul_links[0], 80_000),)
    # jitter wrapper preserves the geo metadata passthrough
    j = scenarios.get("jitter:base=geo,frac=0.1")
    assert j.dc_pop == w.dc_pop and j.dc_lon == w.dc_lon


def test_geo_paths_survive_hop_budget():
    """Span chaining must not starve candidate enumeration: the main
    pair keeps all three parallel hauls as first-hop-distinct
    candidates under the scenario's max_hops budget."""
    scen, table = build_world("geo:dcs=20,chords=10")
    m = table.pair_index()[scen.main_pair]
    assert table.pair_ncand[m] >= 3
    cands = table.pair_cand[m][: table.pair_ncand[m]]
    firsts = table.path_first[cands]
    assert len(set(firsts.tolist())) == len(cands)


# ------------------------------------------------------- registry pins
def test_registry_wire_format_pinned():
    """Scenario names, schedule families and policy codes are wire
    format: sweep cell keys, benchmark CSV rows and pinned acceptance
    thresholds are built from them. Extending any registry is fine —
    renaming or renumbering an existing entry silently invalidates
    recorded results, so this pin must be updated consciously."""
    assert scenarios.names() == [
        "bso13", "bso13_degrade", "geo", "jitter", "longhaul_mesh",
        "parallel", "staleness", "testbed8", "testbed8_failover",
        "wan2000"]
    assert sched.FAMILIES == ("const", "diurnal", "flash")
    assert POLICY_CODES == {
        "lcmp": 0, "lcmp_w": 1, "ecmp": 2, "ucmp": 3, "wcmp": 4,
        "redte": 5, "fatpaths": 6, "amp": 7, "lcmp_r": 8,
        "matchrdma": 9}
    # geo's default parameterization is part of the pin: fig_geo rows
    # embed it, and the scenario string is the sweep static key
    scen = scenarios.get("geo")
    assert scen.name == "geo:dcs=20,chords=10,seed=0"
