"""Mid-flow re-decision plane contracts: the shared decision core must
leave every pinned-path policy bit-for-bit unchanged when the plane is
off, failover must apply each policy's *own* law, the packet engine's
flowlet detector must fire only after a genuine idle gap, and the
amp subflow split must aggregate back to parent flows exactly."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines as bl
from repro.core.select import ecmp_select
from repro.netsim import fluid, metrics, packet, paths, sweep, topo
from repro.netsim.engine import (POLICY_CODES, REDECIDE_POLICIES, SimConfig,
                                 attach_link_caps)
from repro.netsim.experiment import ExpSpec, run_experiment
from repro.traffic.gen import FlowSet, generate


# ------------------------------------------------ frozen policy registry
def test_policy_codes_pinned():
    """The name->code mapping is wire-format: SimArrays.policy_code values
    bake into sweep traces and stored results. Appending is fine;
    renumbering is a silent-corruption bug this pin catches."""
    assert POLICY_CODES == {
        "lcmp": 0, "lcmp_w": 1, "ecmp": 2, "ucmp": 3, "wcmp": 4,
        "redte": 5, "fatpaths": 6, "amp": 7, "lcmp_r": 8, "matchrdma": 9,
    }
    assert REDECIDE_POLICIES == ("fatpaths", "lcmp_r")


# ------------------------------------- plane off => bit-for-bit identical
_OFF_POLICIES = ("lcmp", "lcmp_w", "ecmp", "ucmp", "wcmp", "redte")


@pytest.mark.parametrize("topology", ["testbed8", "wan2000:dcs=6,segs=2"])
def test_knobs_are_inert_for_pinned_policies(topology):
    """Acceptance bar: with re-decision not applicable (policy outside
    REDECIDE_POLICIES), arming the knobs changes *nothing* — every
    existing policy stays bit-for-bit on the testbed and the WAN mesh.
    (``wants_redecide`` is a Python-level gate, so the armed run must
    trace the identical program.)"""
    for pol in _OFF_POLICIES:
        base = ExpSpec(topology=topology, load=0.3, policy=pol,
                       duration_us=60_000, seed=1)
        armed = dataclasses.replace(base, flowlet_gap_us=800,
                                    redecide_period_us=10_000)
        _, _, (_, _, _, _, fa) = run_experiment(base)
        _, _, (_, _, _, _, fb) = run_experiment(armed)
        assert np.array_equal(np.asarray(fa.fct_us), np.asarray(fb.fct_us)), pol
        assert np.array_equal(np.asarray(fa.flow_path),
                              np.asarray(fb.flow_path)), pol
        assert np.array_equal(np.asarray(fa.done), np.asarray(fb.done)), pol


@pytest.mark.parametrize("engine", ["fluid", "packet"])
def test_lcmp_r_knobs_off_is_lcmp_bit_for_bit(engine):
    """lcmp_r with both knobs at 0 is exactly lcmp on both engines — the
    ablation's control cell costs nothing and proves the refactor kept
    the arrival/decision path byte-identical."""
    kw = dict(topology="testbed8", load=0.3, duration_us=60_000, seed=1,
              engine=engine)
    _, _, (_, _, _, _, fa) = run_experiment(ExpSpec(policy="lcmp", **kw))
    _, _, (_, _, _, _, fb) = run_experiment(ExpSpec(policy="lcmp_r", **kw))
    assert np.array_equal(np.asarray(fa.fct_us), np.asarray(fb.fct_us))
    assert np.array_equal(np.asarray(fa.flow_path), np.asarray(fb.flow_path))
    assert np.array_equal(np.asarray(fa.done), np.asarray(fb.done))


def test_mixed_sweep_keeps_pinned_cells_exact():
    """A sweep mixing lcmp with an armed lcmp_r cell shares one trace, so
    the re-decision tick is compiled in — but the per-cell policy_code
    gate must keep the lcmp cell bit-identical to its solo run."""
    kw = dict(topology="testbed8", load=0.3, duration_us=60_000, seed=1,
              redecide_period_us=10_000)
    specs = [ExpSpec(policy="lcmp", **kw), ExpSpec(policy="lcmp_r", **kw)]
    bat = sweep.run_sweep(specs)
    assert bat.num_groups == 1          # same static key: one shared trace
    for i in range(2):
        _, _, (_, _, _, _, solo) = run_experiment(specs[i])
        cell = bat.results[i].final
        assert np.array_equal(np.asarray(cell.fct_us),
                              np.asarray(solo.fct_us)), specs[i].policy
        assert np.array_equal(np.asarray(cell.flow_path),
                              np.asarray(solo.flow_path)), specs[i].policy
        if specs[i].policy == "lcmp_r":
            # the armed cell's tick is live (nonce advances at epochs)
            assert int(np.asarray(solo.route_nonce).max()) > 0


def test_sweep_with_new_policies_matches_sequential():
    """Batched == sequential, bit-for-bit, with the three new policies
    mixed into the dynamic-dispatch plane."""
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol,
                     duration_us=60_000, seed=0)
             for pol in ("lcmp", "fatpaths", "ecmp")]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.flow_path, b.final.flow_path), b.spec
        assert np.array_equal(a.final.done, b.final.done), b.spec


# --------------------------------------- failover under each policy's law
def _hetero_failover(policy):
    t = topo.parallel_paths(caps=(100, 400, 40),
                            delays_us=(5000, 5000, 5000))
    table = paths.build_path_table(t, [(0, 4)])
    attach_link_caps(table, t)
    F = 300
    rng = np.random.default_rng(0)
    flows = FlowSet(arrival_us=np.zeros(F, np.int64),
                    size_bytes=np.full(F, 1e6),
                    pair_id=np.zeros(F, np.int32),
                    flow_id=rng.integers(1, 1 << 32, F, dtype=np.uint32))
    cfg = SimConfig(engine="fluid", policy=policy, horizon_us=60_000)
    arrs, st = fluid.build(table, flows, cfg)
    dead_p = 0
    st = dataclasses.replace(
        st, flow_path=jnp.full_like(st.flow_path, dead_p),
        active=jnp.ones_like(st.active),
        remaining=jnp.full_like(st.remaining, 1e6),
        link_alive=st.link_alive.at[int(table.path_first[dead_p])].set(False))
    out = fluid._reroute_dead(500, st, arrs, cfg)
    return np.asarray(out.flow_path)[:F]


def test_wcmp_failover_uses_capacity_weights_not_ecmp():
    """Satellite regression: before the shared core, ``_reroute_dead``
    failed every policy over with LCMP's selector. wcmp must now re-hash
    capacity-weighted (skewed to the 400G survivor), ecmp uniformly —
    different placements on a heterogeneous-capacity pair."""
    wcmp, ecmp = _hetero_failover("wcmp"), _hetero_failover("ecmp")
    assert not np.array_equal(wcmp, ecmp)
    # survivors are path 1 (400G) and path 2 (40G); wcmp weights 10:1
    w_share = (wcmp == 1).mean()
    e_share = (ecmp == 1).mean()
    assert w_share > 0.75                      # ~10/11 capacity-weighted
    assert 0.35 < e_share < 0.65               # ~1/2 uniform
    # every flow left the dead path under both laws
    assert (wcmp != 0).all() and (ecmp != 0).all()


# ------------------------------------------------ fluid re-decision epoch
def test_fluid_lcmp_r_beats_stale_lcmp_tail():
    """The ablation's reason to exist: under a stale signal plane, pinned
    LCMP parks flows on a degraded haul for their whole lifetime; the
    periodic re-decision epoch lets them escape, so lcmp_r's p99 must
    not be worse. (Empirically ~35% better on this grid; the bound
    leaves slack for numeric drift, not for regression.)"""
    for seed in (1, 2):
        kw = dict(topology="staleness:deg_ms=60", load=0.4, engine="fluid",
                  duration_us=200_000, seed=seed, sig_delay_scale=4.0)
        lcmp, _, _ = run_experiment(ExpSpec(policy="lcmp", **kw))
        lr, _, (_, _, _, _, fin) = run_experiment(
            ExpSpec(policy="lcmp_r", redecide_period_us=10_000, **kw))
        assert int(np.asarray(fin.route_nonce).max()) > 0   # epoch fired
        assert lr.completion_rate >= lcmp.completion_rate
        assert lr.p99 <= lcmp.p99 * 1.05, (seed, lr.p99, lcmp.p99)


# -------------------------------------------- packet-engine flowlet gap
def _flowlet_world(n=12, size=2e5, cap=1):
    """World where a genuine idle gap is reachable: 1G parallel paths so
    the DCQCN saturation floor (~2.6% of line) paces flows well below
    one MTU per slot, every flow hash-pinned to path 0, and a *mild*
    mid-run degrade (x0.5) so the shared queue floors the rates but
    still drains while the flows are alive."""
    t = topo.parallel_paths(caps=(cap, cap), delays_us=(200, 200))
    table = paths.build_path_table(t, [(0, 3)])
    attach_link_caps(table, t)
    fids = np.arange(1, 4000, dtype=np.uint32)
    k = np.asarray(ecmp_select(jnp.asarray(fids),
                               jnp.ones((len(fids), 2), bool)))
    on0 = fids[k == 0][:n]
    flows = FlowSet(arrival_us=np.full(n, 1000, np.int64),
                    size_bytes=np.full(n, float(size)),
                    pair_id=np.zeros(n, np.int32),
                    flow_id=np.array(on0, np.uint32))
    return table, flows


def _flowlet_run(table, flows, gap_us, degrade=True):
    deg = ((int(table.path_first[0]), 5000, 0.5),) if degrade else ()
    cfg = SimConfig(engine="packet", policy="fatpaths",
                    horizon_us=1_000_000, flowlet_gap_us=gap_us,
                    ecn_kmin_bytes=2e4, degrade_sched=deg)
    arrs, st = packet.build(table, flows, cfg)
    return packet.run(arrs, st, cfg)


def test_packet_flowlet_fires_after_genuine_idle_gap():
    """Positive case: the mid-run degrade floors the co-located flows'
    rates below one MTU/slot; once the backlog drains, their paced
    injections leave multi-slot idle gaps, the detector fires, and the
    re-hash actually moves traffic onto the clean path — all of it only
    *after* the degrade hit."""
    table, flows = _flowlet_world()
    f = _flowlet_run(table, flows, gap_us=800)
    nonce = np.asarray(f.route_nonce)
    fp = np.asarray(f.flow_path)
    assert (nonce > 0).sum() >= len(nonce) // 2      # detector fired
    moved = fp == 1
    assert moved.any()                               # traffic re-balanced
    deg_step = 5000 // int(f.rtt_steps.dtype.type(200))  # 200us slots
    assert (np.asarray(f.route_step)[moved] > deg_step).all()
    assert np.asarray(f.done).all()


def test_packet_flowlet_needs_idle_not_just_time():
    """Negative cases: (a) an uncongested pair never drains below one
    in-flight packet-gap, so an armed detector must stay silent and the
    run must be bit-identical to gap=0; (b) on the degraded world a gap
    threshold far above the real idle runs must also never fire."""
    t = topo.parallel_paths(caps=(1, 1), delays_us=(200, 200))
    table = paths.build_path_table(t, [(0, 3)])
    attach_link_caps(table, t)
    flows = FlowSet(arrival_us=np.array([1000, 1000], np.int64),
                    size_bytes=np.array([2e5, 2e5]),
                    pair_id=np.zeros(2, np.int32),
                    flow_id=np.array([42, 99], np.uint32))
    armed = _flowlet_run(table, flows, gap_us=800, degrade=False)
    off = _flowlet_run(table, flows, gap_us=0, degrade=False)
    assert int(np.asarray(armed.route_nonce).max()) == 0
    assert np.array_equal(np.asarray(armed.fct_us), np.asarray(off.fct_us))
    assert np.array_equal(np.asarray(armed.flow_path),
                          np.asarray(off.flow_path))
    # (b) same congested world as the positive case, threshold too high
    table, flows = _flowlet_world()
    f = _flowlet_run(table, flows, gap_us=400_000)
    assert int(np.asarray(f.route_nonce).max()) == 0


# --------------------------------------------------- amp subflow plumbing
def test_amp_generator_split_invariants():
    from repro.netsim.experiment import build_world
    from repro.traffic import cdf as cdfmod
    _, table = build_world("testbed8")
    kw = dict(load=0.3, duration_us=60_000, pair_ids=[0], seed=3)
    base = generate(table, cdfmod.WORKLOADS["websearch"], **kw)
    split = generate(table, cdfmod.WORKLOADS["websearch"], n_subflows=3, **kw)
    n = len(base.arrival_us)
    assert base.subflow_of is None                # legacy sets untouched
    assert len(split.arrival_us) == 3 * n
    assert np.array_equal(split.subflow_of, np.repeat(np.arange(n), 3))
    # parent byte counts preserved exactly by the equal split
    np.testing.assert_allclose(
        np.add.reduceat(split.size_bytes, np.arange(0, 3 * n, 3)),
        base.size_bytes)
    assert np.array_equal(np.repeat(base.arrival_us, 3), split.arrival_us)
    assert np.array_equal(np.repeat(base.pair_id, 3), split.pair_id)
    # subflow hash keys: all nonzero, and siblings never collide (a
    # collision would silently collapse two subflows onto one ECMP draw)
    ids = split.flow_id.reshape(n, 3)
    assert (ids != 0).all()
    assert all(len(set(row)) == 3 for row in ids)


def test_amp_metrics_score_parent_at_last_subflow():
    from types import SimpleNamespace
    t = topo.parallel_paths(caps=(100,), delays_us=(1000,))
    table = paths.build_path_table(t, [(0, 2)])
    attach_link_caps(table, t)
    # two parents x 2 subflows: parent 0 complete (last lands at 900),
    # parent 1 has one straggler -> not done
    flows = FlowSet(arrival_us=np.zeros(4, np.int64),
                    size_bytes=np.array([500.0, 500.0, 300.0, 300.0]),
                    pair_id=np.zeros(4, np.int32),
                    flow_id=np.array([1, 2, 3, 4], np.uint32),
                    subflow_of=np.array([0, 0, 1, 1], np.int32))
    final = SimpleNamespace(done=np.array([True, True, True, False]),
                            fct_us=np.array([900.0, 400.0, 100.0, 0.0]))
    cfg = SimConfig(engine="fluid", policy="ecmp", horizon_us=10_000)
    stats = metrics.fct_stats(final, table, flows, cfg)
    assert stats.offered == 2 and stats.completed == 1
    ideal = (float(table.pair_ideal_prop[0])
             + 1000.0 / (float(table.pair_ideal_cap[0]) * 125.0
                         * cfg.cap_scale))
    np.testing.assert_allclose(stats.slowdown,
                               [max(900.0 / ideal, 1.0)])
    np.testing.assert_allclose(stats.sizes, [1000.0])


def test_amp_end_to_end_completes():
    """amp runs through the full stack (gen split -> per-subflow ECMP ->
    parent-level stats): offered counts parents, not subflows, and the
    quiet testbed completes everything."""
    stats, _, (_, _, flows, _, _) = run_experiment(
        ExpSpec(topology="testbed8", load=0.3, policy="amp", n_subflows=4,
                duration_us=60_000, seed=1))
    assert flows.subflow_of is not None
    assert stats.offered == int(flows.subflow_of.max()) + 1
    assert stats.completion_rate > 0.95


# ------------------------------------------------------- fatpaths layers
def test_fatpaths_prefers_min_stretch_layer_and_spills():
    F = 64
    fids = np.arange(1, F + 1, dtype=np.uint32)
    plen = jnp.asarray(np.tile([2, 2, 4, 4], (F, 1)), jnp.int32)
    valid = jnp.ones((F, 4), bool)
    cool = jnp.zeros((F, 4), jnp.float32)
    # uncongested: every choice stays in the min-hop layer {0, 1}
    k = np.asarray(bl.fatpaths(jnp.asarray(fids), plen, valid, cool))
    assert set(k) <= {0, 1} and len(set(k)) == 2     # layered ECMP spread
    # layer-0 congestion beyond the threshold: spill to the full set
    hot = jnp.asarray(np.tile([255.0, 255.0, 0.0, 0.0], (F, 1)),
                      jnp.float32)
    k = np.asarray(bl.fatpaths(jnp.asarray(fids), plen, valid, hot))
    assert {2, 3} & set(k)                           # long paths now used
    # invalid candidates are never chosen even when the layer is hot
    valid2 = jnp.asarray(np.tile([True, True, False, False], (F, 1)))
    k = np.asarray(bl.fatpaths(jnp.asarray(fids), plen, valid2, hot))
    assert set(k) <= {0, 1}
