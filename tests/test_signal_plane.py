"""Signal-plane fidelity contracts: routed congestion signals obey
propagation delay, the control plane re-installs C_path on its period
(and only then), failover re-initializes CC state, and the history ring
rejects configurations it cannot represent."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pathq import calc_path_quality
from repro.netsim import fluid, paths, scenarios, topo
from repro.netsim.experiment import ExpSpec, build_experiment
from repro.netsim.fluid import SimConfig


# ------------------------------------------- propagation-delayed visibility
def test_remote_congestion_invisible_before_one_way_prop():
    """A remote hop's congestion score recorded at step t0 must not reach
    the ingress decision before t0 + its backward propagation delay."""
    d = 50
    hist_c = np.zeros((2, fluid.HIST), np.int32)
    t0 = 1000
    # reprolint: ignore[RNG001] host-side setup writes one in-range slot
    hist_c[1, t0] = 200                     # remote hop flags congestion
    pl = jnp.asarray([[0, 1, -1]])          # one path: local hop, remote hop
    sd = jnp.asarray([[0, d, 0]])           # remote signal is d steps away
    for t, expect in [(t0, 0), (t0 + d - 1, 0), (t0 + d, 200),
                      (t0 + d + 1, 0)]:     # (one-step pulse moves past)
        v = fluid.path_cong_view(jnp.asarray(hist_c), pl, sd, t)
        assert int(v[0]) == expect, (t, expect)


def test_local_hop_reads_current_score_and_max_over_hops():
    hist_c = np.zeros((2, fluid.HIST), np.int32)
    hist_c[0, 7] = 40                       # local hop, current step
    hist_c[1, 7] = 90                       # remote hop, same step
    pl = jnp.asarray([[0, 1]])
    v_now = fluid.path_cong_view(jnp.asarray(hist_c), pl,
                                 jnp.asarray([[0, 0]]), 7)
    assert int(v_now[0]) == 90              # zero delay: max over both hops
    v_dly = fluid.path_cong_view(jnp.asarray(hist_c), pl,
                                 jnp.asarray([[0, 30]]), 7)
    assert int(v_dly[0]) == 40              # remote entry not yet arrived


def test_build_precomputes_cumulative_upstream_delays():
    """path_sig_delay[h] = scaled sum of upstream hop propagation; hop 0
    (the ingress's own egress port) is always 0."""
    t = topo.segmented_parallel([100], [120_000], segs=3)
    table = paths.build_path_table(t, [(0, t.num_nodes - 1)])
    fluid.attach_link_caps(table, t)
    from repro.traffic.gen import FlowSet
    flows = FlowSet(arrival_us=np.array([0], np.int64),
                    size_bytes=np.array([1e6]),
                    pair_id=np.array([0], np.int32),
                    flow_id=np.array([1], np.uint32))
    for scale in (1.0, 2.0):
        cfg = SimConfig(dt_us=200, sig_delay_scale=scale)
        arr, _ = fluid.build(table, flows, cfg)
        sig = np.asarray(arr.path_sig_delay[0])
        seg = 40_000  # 120 ms split over 3 segments
        want = (scale * np.array([0, seg, 2 * seg, 3 * seg]) // 200)
        assert (sig[:4] == want).all(), (scale, sig)


def test_build_rejects_history_ring_overflow():
    """Satellite: HIST carries a "must exceed max RTT" invariant — build()
    must enforce it instead of silently wrapping the ring."""
    t = topo.parallel_paths(caps=(100,), delays_us=(250_000,))
    table = paths.build_path_table(t, [(0, 2)])
    fluid.attach_link_caps(table, t)
    from repro.traffic.gen import FlowSet
    flows = FlowSet(arrival_us=np.array([0], np.int64),
                    size_bytes=np.array([1e6]),
                    pair_id=np.array([0], np.int32),
                    flow_id=np.array([1], np.uint32))
    with pytest.raises(ValueError, match="HIST"):        # rtt overflow
        fluid.build(table, flows, SimConfig(dt_us=10))
    with pytest.raises(ValueError, match="sig_delay_scale"):  # offset overflow
        fluid.build(table, flows, SimConfig(dt_us=200, sig_delay_scale=40.0))
    fluid.build(table, flows, SimConfig(dt_us=200))      # sane cfg passes


# --------------------------------------------------- control-plane refresh
def _degrade_world(ctrl_period_us, horizon_us, deg_at_us=10_000, factor=0.25):
    spec = ExpSpec(topology="parallel:n=2,cap=100", load=0.3, policy="ecmp",
                   duration_us=60_000, seed=3)
    _, table, flows, cfg = build_experiment(spec)
    first = int(table.path_first[0])
    cfg = dataclasses.replace(cfg, horizon_us=horizon_us,
                              ctrl_period_us=ctrl_period_us,
                              degrade_sched=((first, deg_at_us, factor),))
    arrs, st = fluid.build(table, flows, cfg)
    return table, cfg, arrs, st, first


def test_degrade_changes_c_path_after_and_only_after_refresh():
    """deg at 10 ms, refresh period 20 ms: the installed score must be
    unchanged at 16 ms (last refresh predates the degrade) and repriced
    by 24 ms (first refresh after it)."""
    table, cfg, arrs, st, _ = _degrade_world(ctrl_period_us=20_000,
                                             horizon_us=16_000)
    initial = np.asarray(st.c_path).copy()
    before = fluid.run(arrs, st, cfg)
    assert np.array_equal(np.asarray(before.c_path), initial)

    table, cfg, arrs, st, _ = _degrade_world(ctrl_period_us=20_000,
                                             horizon_us=24_000)
    after = fluid.run(arrs, st, cfg)
    got = np.asarray(after.c_path)
    assert got[0] > initial[0]          # degraded path repriced upward
    assert got[1] == initial[1]         # untouched path unchanged


def test_ctrl_period_zero_freezes_build_time_table():
    table, cfg, arrs, st, _ = _degrade_world(ctrl_period_us=0,
                                             horizon_us=40_000)
    final = fluid.run(arrs, st, cfg)
    assert np.array_equal(np.asarray(final.c_path), np.asarray(st.c_path))


def test_ctrl_refresh_matches_pathq_on_effective_caps():
    """The refresh output is exactly core.pathq over per-path bottlenecks
    of the effective (degraded) link capacities."""
    table, cfg, arrs, st, first = _degrade_world(ctrl_period_us=20_000,
                                                 horizon_us=24_000)
    t_after = cfg.num_steps - 1
    got = fluid.ctrl_refresh(t_after, st, arrs, cfg)
    # independent numpy reconstruction: degrade the link, min over hops
    eff_link = np.asarray(arrs.link_cap_gbps, np.float64)
    eff_link[first] *= 0.25
    pl = np.asarray(table.path_links)
    eff_path = np.where(pl >= 0, eff_link[np.maximum(pl, 0)],
                        np.inf).min(-1)
    want = calc_path_quality(jnp.asarray(table.path_prop_us),
                             jnp.asarray(eff_path.astype(np.int32)),
                             arrs.tables.cap_thresh, cfg.pathq)
    assert np.array_equal(np.asarray(got), np.asarray(want))


# ------------------------------------------------------- failover CC reset
def test_reroute_dead_reinitializes_cc_state():
    """Satellite regression: a failed-over flow must restart CC on the new
    path — fresh target, fresh MD timer, the NEW path's standing queue —
    not blast at line rate against the dead path's AIMD remnants."""
    spec = ExpSpec(topology="parallel:n=2,cap=100", load=0.3, policy="lcmp",
                   duration_us=60_000, seed=1)
    scen, table, flows, cfg = build_experiment(spec)
    arrs, st = fluid.build(table, flows, cfg)
    t = 500
    # the main pair's two candidate paths (global indices)
    main = table.pair_index()[(0, 3)]
    dead_p, live_p = (int(x) for x in table.pair_cand[main][:2])
    dead_first = int(table.path_first[dead_p])
    surv_first = int(table.path_first[live_p])
    alive_q = 2e6                                # standing queue, live path
    st = dataclasses.replace(
        st,
        flow_path=st.flow_path.at[0].set(dead_p),
        active=st.active.at[0].set(True),
        remaining=st.remaining.at[0].set(1e8),
        rate=st.rate.at[0].set(1.0),
        cc_target=st.cc_target.at[0].set(1.0),   # deep AIMD backoff remnants
        last_dec=st.last_dec.at[0].set(t - 1),
        cc_alpha=st.cc_alpha.at[0].set(0.5),
        extra_wait=st.extra_wait.at[0].set(1234.5),
        q_bytes=st.q_bytes.at[surv_first].set(alive_q),
        link_alive=st.link_alive.at[dead_first].set(False))
    out = fluid._reroute_dead(t, st, arrs, cfg)
    assert int(out.flow_path[0]) == live_p       # moved to the live path
    line = float(arrs.path_cap[live_p])
    assert float(out.rate[0]) == line
    assert float(out.cc_target[0]) == line       # target re-initialized
    assert int(out.last_dec[0]) == -(1 << 20)    # MD timer reset
    assert float(out.cc_alpha[0]) == 0.0
    want_qw = alive_q / float(arrs.link_cap[surv_first])
    assert np.isclose(float(out.extra_wait[0]), want_qw)  # new path's queue


# --------------------------------------------------- end-to-end staleness
def test_staleness_hurts_reactive_policies_ecmp_flat():
    """Acceptance: a stale routing signal worsens LCMP's tail on the
    staleness scenario (remote-span degrade, control plane frozen so only
    the signal-plane knob acts), while ECMP — which never reads the
    congestion signal — is bit-for-bit flat. The hurt is asserted on the
    seed-averaged p99 for each stale point against the fresh view; past
    the queue-buildup timescale extra staleness saturates rather than
    compounding, so no strict ordering *between* stale points is claimed.
    The grid runs batched through the sweep engine (sig_delay_scale is a
    static axis: one trace per value; policy x seed stay dynamic)."""
    from repro.netsim.sweep import run_sweep
    seeds, sdss = (1, 2, 3), (0.0, 2.0, 6.0)
    specs = [ExpSpec(topology="staleness:deg_ms=60", load=0.4, policy=pol,
                     duration_us=300_000, seed=seed, sig_delay_scale=sds,
                     ctrl_period_us=0)
             for sds in sdss for seed in seeds for pol in ("lcmp", "ecmp")]
    rep = run_sweep(specs)
    res = {(r.spec.sig_delay_scale, r.spec.seed, r.spec.policy): r
           for r in rep.results}
    p99 = {sds: np.mean([res[(sds, seed, "lcmp")].stats.p99
                         for seed in seeds]) for sds in sdss}
    assert p99[0.0] < p99[2.0], p99
    assert p99[0.0] < p99[6.0], p99
    for seed in seeds:
        fct = [res[(sds, seed, "ecmp")].final.fct_us for sds in sdss]
        assert np.array_equal(fct[0], fct[1]) and np.array_equal(fct[0], fct[2])


def test_staleness_scenario_targets_a_remote_span():
    """The degraded link must not be a first hop of any candidate path —
    otherwise the ablation is vacuous (zero signal delay)."""
    scen = scenarios.get("staleness")
    table = paths.build_path_table(scen.topology,
                                   paths.all_pairs(scen.topology))
    deg = scen.degrade_sched[0][0]
    main = table.pair_index()[scen.main_pair]
    cands = table.pair_cand[main][: table.pair_ncand[main]]
    assert deg not in set(table.path_first[cands].tolist())
    assert any(deg in table.path_links[p] for p in cands)
