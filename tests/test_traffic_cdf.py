"""Workload CDF regression: sampling must invert the CDF in *log-size*
space (as documented — the published breakpoints are log-spaced samples
of smooth heavy-tailed curves), and ``mean()`` must be the exact mean of
what ``sample`` draws, because load calibration divides by it."""
import numpy as np
import pytest

from repro.traffic.cdf import ALI_STORAGE, FB_HADOOP, WEB_SEARCH, WORKLOADS

ALL = [WEB_SEARCH, FB_HADOOP, ALI_STORAGE]


@pytest.mark.parametrize("cdf", ALL, ids=lambda c: c.name)
def test_sample_inverts_cdf_in_log_space(cdf):
    """A draw at quantile u inside segment [p_i, p_{i+1}) must be the
    *geometric* interpolation of the endpoint sizes, not the arithmetic
    one (checked at explicit mid-quantiles of interior segments)."""
    rng = np.random.default_rng(0)
    for i in range(len(cdf.probs) - 1):
        p0, p1 = cdf.probs[i], cdf.probs[i + 1]
        s0, s1 = cdf.sizes[i], cdf.sizes[i + 1]
        u = (p0 + p1) / 2

        class FixedU:
            def uniform(self, lo, hi, n):
                return np.full(n, u)
        got = cdf.__class__.sample(cdf, FixedU(), 3)
        want = np.exp((np.log(s0) + np.log(s1)) / 2)   # geometric midpoint
        assert np.allclose(got, want, rtol=1e-12), (cdf.name, i)
        # regression against the old linear-size bias: the arithmetic
        # midpoint is strictly larger on every non-degenerate segment
        if s1 > 1.0001 * s0:
            assert got[0] < (s0 + s1) / 2, (cdf.name, i)
    del rng


@pytest.mark.parametrize("cdf", ALL, ids=lambda c: c.name)
def test_mean_matches_empirical_sample_mean(cdf):
    """mean() is the analytic mean of the log-space sampler (logarithmic
    segment means) — the empirical mean of a large draw must converge to
    it, so load calibration doses the intended byte rate."""
    rng = np.random.default_rng(7)
    emp = cdf.sample(rng, 400_000).mean()
    assert abs(emp - cdf.mean()) / cdf.mean() < 0.02, (cdf.name, emp, cdf.mean())


def test_pinned_means_and_quantiles():
    """Pin the three published workloads' analytic means and mid/tail
    quantiles of the log-space inversion (values recorded at the fix;
    any drift in breakpoints or interpolation shows up here)."""
    pins = {
        "websearch": dict(mean=235947.2, q50=6477.0, q90=159054.1,
                          q99=5000000.0),
        "fbhdp": dict(mean=218913.6, q50=500.0, q90=100000.0,
                      q99=6309573.4),
        "alistorage": dict(mean=874058.0, q50=4000.0, q90=1000000.0,
                           q99=16000000.0),
    }
    for name, pin in pins.items():
        cdf = WORKLOADS[name]
        assert np.isclose(cdf.mean(), pin["mean"], rtol=1e-3), (
            name, cdf.mean())
        for q, want in [(0.5, pin["q50"]), (0.9, pin["q90"]),
                        (0.99, pin["q99"])]:
            got = float(np.exp(np.interp(q, cdf.probs, np.log(cdf.sizes))))
            assert np.isclose(got, want, rtol=1e-3), (name, q, got)


def test_log_space_fix_shrinks_heavy_tail_bias():
    """The documented bug: linear-size interpolation biased heavy-tail
    draws upward. The fixed sampler's mean must sit strictly below the
    arithmetic-midpoint mean of the old interpolation for every
    workload (log-mean < arithmetic mean on non-degenerate segments)."""
    for cdf in ALL:
        mid = (cdf.sizes[1:] + cdf.sizes[:-1]) / 2
        old_mean = float((mid * np.diff(cdf.probs)).sum()
                         + cdf.sizes[0] * cdf.probs[0])
        assert cdf.mean() < old_mean, cdf.name
