"""End-to-end tests of the composed DCI switch state machine (Fig. 2):
stickiness, GC, lazy fast-failover, and the full routing workflow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import switchd, tables
from repro.core import flowcache as fc

# 6 candidate paths (Fig. 1): {200,200,100,100,40,40} Gbps x {5,250} ms
DELAYS = jnp.array([5_000, 250_000, 5_000, 250_000, 5_000, 250_000])
CAPS = jnp.array([200, 200, 100, 100, 40, 40])
PORTS = jnp.arange(6, dtype=jnp.int32)


def _mk(cache_capacity=512):
    tb = tables.bootstrap_tables([200, 200, 100, 100, 40, 40],
                                 buffer_bytes=6 * 10**9)
    return switchd.make_switch(tb, DELAYS, CAPS, PORTS, num_ports=6,
                               cache_capacity=cache_capacity)


def test_first_packet_decides_second_sticks():
    sw = _mk()
    fids = jnp.array([101, 202, 303], dtype=jnp.uint32)
    sw, idx1, new1 = switchd.route_batch(sw, fids, now_us=0)
    assert np.asarray(new1).all()
    sw, idx2, new2 = switchd.route_batch(sw, fids, now_us=10)
    assert not np.asarray(new2).any()
    assert np.array_equal(np.asarray(idx1), np.asarray(idx2))  # stickiness


def test_gc_evicts_idle_flows():
    sw = _mk()
    fids = jnp.array([7], dtype=jnp.uint32)
    sw, idx1, _ = switchd.route_batch(sw, fids, now_us=0)
    p = switchd.SwitchParams(idle_timeout_us=1000)
    sw = switchd.gc_tick(sw, now_us=5000, params=p)
    _, _, new = switchd.route_batch(sw, fids, now_us=5001)
    assert np.asarray(new).all()  # entry was garbage-collected


def test_lazy_failover_rehashes_to_live_port():
    sw = _mk()
    fids = (jnp.arange(200, dtype=jnp.uint32) * jnp.uint32(2654435761))
    sw, idx1, _ = switchd.route_batch(sw, fids, now_us=0)
    dead_port = int(np.bincount(np.asarray(idx1), minlength=6).argmax())
    alive = jnp.ones(6, bool).at[dead_port].set(False)
    sw = switchd.set_port_liveness(sw, alive)
    sw, idx2, renew = switchd.route_batch(sw, fids, now_us=10)
    idx2 = np.asarray(idx2)
    assert (idx2 != dead_port).all()               # nobody lands on dead port
    moved = np.asarray(idx1) == dead_port
    assert np.asarray(renew)[moved].all()          # dead-port flows re-decide
    # non-moved flows stay sticky unless their direct-mapped slot collided
    same = ~np.asarray(renew)
    assert (idx2[same] == np.asarray(idx1)[same]).all()
    assert same[~moved].mean() > 0.7               # few collisions only


def test_routing_prefers_low_delay_paths_when_uncongested():
    sw = _mk()
    fids = (jnp.arange(2000, dtype=jnp.uint32) * jnp.uint32(40503) + 17)
    sw, idx, _ = switchd.route_batch(sw, fids, now_us=0)
    counts = np.bincount(np.asarray(idx), minlength=6)
    # C_path with (3,1): low-delay paths (0,2,4) dominate the kept set
    assert counts[[1, 3, 5]].sum() == 0, counts
    assert counts[[0, 2, 4]].min() > 0


def test_congestion_shifts_traffic_away():
    """A persistently growing queue on one of the comparable low-delay
    paths must push that path out of the kept set (C_cong at work).

    Note the deliberate topology: among paths with *similar* delay the
    congestion term decides; across a 50x delay gap the paper's (3,1)
    fusion keeps path quality dominant (tested above)."""
    tb = tables.bootstrap_tables([100, 100, 100, 100], buffer_bytes=6 * 10**9)
    sw = switchd.make_switch(tb, jnp.array([5_000, 5_000, 20_000, 20_000]),
                             jnp.array([100, 100, 100, 100]),
                             jnp.arange(4, dtype=jnp.int32), num_ports=4)
    # port 0: queue grows every sample and stays above high water -> Q,T,D all fire
    for i in range(300):
        q = jnp.zeros(4, jnp.int32).at[0].set((4 + i // 40) * 10**9 // 1024)
        sw = switchd.monitor_tick(sw, q, now_us=i * 100)
    fids = (jnp.arange(2000, dtype=jnp.uint32) * jnp.uint32(48271) + 3)
    sw, idx, _ = switchd.route_batch(sw, fids, now_us=30_100)
    counts = np.bincount(np.asarray(idx), minlength=4)
    assert counts[0] == 0, counts   # congested low-delay path filtered out
    assert counts[1] > 0            # clean low-delay twin carries traffic


def test_route_batch_jittable():
    sw = _mk()
    fids = jnp.arange(64, dtype=jnp.uint32)
    f = jax.jit(lambda s, x: switchd.route_batch(s, x, now_us=0))
    sw2, idx, new = f(sw, fids)
    assert idx.shape == (64,)


def test_flowcache_direct_mapped_collision_overwrite():
    cache = fc.FlowCache.init(4)
    ids = jnp.array([1, 2, 3, 4, 5], dtype=jnp.uint32)
    cache = fc.insert(cache, ids, jnp.arange(5, dtype=jnp.int32), 0,
                      jnp.ones(5, bool))
    hit, out, _ = fc.lookup(cache, ids, jnp.ones(8, bool))
    assert int(np.asarray(hit).sum()) <= 4  # bounded state


def test_per_flow_and_per_port_storage_budget():
    """Paper §4: 24 B/port, 20 B/flow, 50k flows ~= 1.2 MB."""
    per_port = 4 + 4 + 4 + 4 + 8          # queueCur,queuePrev,trend,durCnt,lastSample
    per_flow = 8 + 4 + 8                  # flowId, portIdx, lastSeen
    assert per_port == 24 and per_flow == 20
    assert 48 * per_port == 1152
    assert abs(50_000 * 24 - 1.2e6) / 1.2e6 < 0.01
