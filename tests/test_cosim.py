"""Training co-simulation contracts (``repro.cosim``): the collective
overlay keeps the legacy background rng draw sequence **bit-for-bit**
(property-tested over seeds/loads), default cosim knobs are inert at
the flow-table AND engine level, the four cosim ExpSpec fields batch as
dynamic sweep axes on both engines (matchrdma included), iteration
makespans follow barrier semantics with survivorship-safe percentiles,
and the measured-time feedback seam demotes a persistently slow
simulated route in ``dist.lcmp_collectives``' scheduler."""
import dataclasses
from types import SimpleNamespace

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.cosim import (build_plan, feed_route_telemetry, iteration_stats,
                         overlay, pair_path_slots, straggler_routes)
from repro.cosim.workload import (GRAD_BYTES_PER_PARAM, PODS, CosimPlan,
                                  bucket_wire_bytes)
from repro.dist import lcmp_collectives as lc
from repro.dist.lcmp_collectives import BUCKET_ELEMS
from repro.kernels.qsr_int8 import BLOCK
from repro.netsim import sweep
from repro.netsim.experiment import ExpSpec, build_world, make_flows

TOP = "wan2000:dcs=8,segs=2,chords=4"


def _spec(**kw):
    base = dict(topology=TOP, load=0.3, duration_us=60_000, seed=3,
                cap_scale=0.0625, cosim_model="qwen3-4b", cosim_iters=4)
    base.update(kw)
    return ExpSpec(**base)


# ----------------------------------------------------------- plan structure
def test_plan_matches_collective_accounting():
    """The plan's bucket count and per-leg wire bytes are exactly the
    ``lcmp_pod_reduce`` accounting — bucketization by BUCKET_ELEMS,
    int8 + one f32 scale per BLOCK when compressed, times the
    (pods-1)/pods fraction each collective leg moves."""
    scen, table = build_world(TOP)
    spec = _spec()
    plan = build_plan(spec, scen, table)
    params = plan.param_count
    nb = -(-params // BUCKET_ELEMS)
    assert plan.n_buckets == nb
    assert plan.num_rows == spec.cosim_iters * 2 * nb   # RS + AG per iter
    wire = bucket_wire_bytes(params, True)
    lens = np.minimum((np.arange(nb) + 1) * BUCKET_ELEMS,
                      params) - np.arange(nb) * BUCKET_ELEMS
    np.testing.assert_array_equal(wire, lens + 4 * (-(-lens // BLOCK)))
    assert bucket_wire_bytes(params, False).sum() \
        == GRAD_BYTES_PER_PARAM * params
    rs = plan.phase_of == 0
    np.testing.assert_allclose(
        plan.size_bytes[rs][:nb], wire * (PODS - 1) / PODS)
    # deterministic and rng-free: same spec, same rows
    again = build_plan(spec, scen, table)
    np.testing.assert_array_equal(plan.arrival_us, again.arrival_us)
    np.testing.assert_array_equal(plan.flow_id, again.flow_id)
    assert (plan.flow_id != 0).all()


def test_plan_phases_and_pairs():
    """RS bursts stagger inside the first quarter of each iteration on
    the forward pair; AG bursts follow half a period later on the
    reverse pair (wan2000 advertises both directions)."""
    scen, table = build_world(TOP)
    spec = _spec()
    plan = build_plan(spec, scen, table)
    pidx = table.pair_index()
    fwd = pidx[scen.main_pair]
    rev = pidx[(scen.main_pair[1], scen.main_pair[0])]
    rs, ag = plan.phase_of == 0, plan.phase_of == 1
    assert (plan.pair_id[rs] == fwd).all()
    assert (plan.pair_id[ag] == rev).all()
    rel = plan.arrival_us - plan.iter_start_us(plan.iter_of)
    assert (rel[rs] < plan.period_us * 0.25).all()
    assert (rel[ag] >= plan.period_us * 0.5).all()
    assert (rel < plan.period_us).all()


def test_plan_validation():
    scen, table = build_world(TOP)
    with pytest.raises(ValueError, match="train cell"):
        build_plan(_spec(cosim_cell="prefill_32k"), scen, table)
    with pytest.raises(ValueError, match="cosim_iters"):
        build_plan(_spec(cosim_iters=0), scen, table)


# ---------------------------------------- background bit-for-bit (property)
@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=7),
       st.sampled_from([0.15, 0.3, 0.5]),
       st.sampled_from([0.0, 0.1]))
def test_overlay_keeps_background_bitforbit(seed, load, bg):
    """THE invariant: for arbitrary seed/load/bg_load, every background
    row of the cosim flow table carries the exact legacy value, in the
    exact legacy relative order — the collective rows only interleave.
    (The plan is rng-free and the merge sort is stable.)"""
    scen, table = build_world(TOP)
    legacy = make_flows(_spec(seed=seed, load=load, bg_load=bg,
                              cosim_model=""), scen, table)
    cos = make_flows(_spec(seed=seed, load=load, bg_load=bg), scen, table)
    assert cos.cosim_of is not None
    bgm = np.asarray(cos.cosim_of) < 0
    np.testing.assert_array_equal(cos.arrival_us[bgm], legacy.arrival_us)
    np.testing.assert_array_equal(cos.size_bytes[bgm], legacy.size_bytes)
    np.testing.assert_array_equal(cos.pair_id[bgm], legacy.pair_id)
    np.testing.assert_array_equal(cos.flow_id[bgm], legacy.flow_id)
    np.testing.assert_array_equal(cos.foreground[bgm], legacy.foreground)
    np.testing.assert_array_equal(cos.dose_target, legacy.dose_target)
    np.testing.assert_array_equal(cos.dose_real, legacy.dose_real)
    # merged table stays arrival-sorted, and every plan row is present
    assert (np.diff(cos.arrival_us) >= 0).all()
    plan = build_plan(_spec(seed=seed, load=load, bg_load=bg), scen, table)
    assert (~bgm).sum() == plan.num_rows
    assert cos.foreground[~bgm].all()       # collectives are the workload


def test_overlay_with_subflows_joins_singleton_parents():
    """Under amp subflow generation the collective rows join as
    singleton parents: parent-level metrics stay well-defined and the
    background parent ids are untouched."""
    scen, table = build_world(TOP)
    legacy = make_flows(_spec(n_subflows=2, cosim_model=""), scen, table)
    cos = make_flows(_spec(n_subflows=2), scen, table)
    bgm = np.asarray(cos.cosim_of) < 0
    np.testing.assert_array_equal(cos.subflow_of[bgm], legacy.subflow_of)
    cs = cos.subflow_of[~bgm]
    assert len(np.unique(cs)) == len(cs)            # singletons
    assert cs.min() > legacy.subflow_of.max()


# -------------------------------------------------------- defaults are inert
def test_default_knobs_are_inert():
    """cosim_model="" disables the overlay entirely — the flow table is
    bit-for-bit the legacy generate() output (cosim_of absent), no
    matter what the other cosim knobs say."""
    scen, table = build_world(TOP)
    base = make_flows(_spec(cosim_model=""), scen, table)
    assert base.cosim_of is None
    for kw in (dict(cosim_iters=11,), dict(cosim_compress=0),
               dict(cosim_cell="train_4k")):
        other = make_flows(_spec(cosim_model="", **kw), scen, table)
        np.testing.assert_array_equal(base.arrival_us, other.arrival_us)
        np.testing.assert_array_equal(base.flow_id, other.flow_id)
        np.testing.assert_array_equal(base.size_bytes, other.size_bytes)


@pytest.mark.parametrize("engine", ["fluid", "packet"])
def test_default_knobs_engine_run_bit_identical(engine):
    """Engine-level inertness for the pre-existing policies: a run with
    default cosim knobs reproduces the pre-cosim simulation exactly —
    every FCT, path choice and completion bit."""
    specs = [ExpSpec(topology="testbed8", load=0.3, duration_us=50_000,
                     seed=1, engine=engine, policy=pol, cosim_iters=it)
             for pol in ("lcmp", "ecmp", "wcmp", "fatpaths")
             for it in (6, 3)]       # cosim_iters moot while model=""
    rep = sweep.run_sweep(specs, sequential=True)
    for pol in ("lcmp", "ecmp", "wcmp", "fatpaths"):
        a, b = [r for r in rep.results if r.spec.policy == pol]
        assert np.array_equal(np.asarray(a.final.fct_us),
                              np.asarray(b.final.fct_us))
        assert np.array_equal(np.asarray(a.final.flow_path),
                              np.asarray(b.final.flow_path))
        assert np.array_equal(np.asarray(a.final.done),
                              np.asarray(b.final.done))


# ------------------------------------------------- cosim axes batch (sweep)
@pytest.mark.parametrize("engine", ["fluid", "packet"])
def test_cosim_axes_sweep_bit_for_bit(engine):
    """The four cosim fields are dynamic axes: a grid mixing cosim
    on/off, model, iters and compression (with matchrdma among the
    policies) reproduces the sequential loop exactly on both engines."""
    specs = [_spec(duration_us=50_000, engine=engine, policy=pol,
                   cosim_model=m, cosim_iters=it, cosim_compress=cp)
             for (m, it, cp) in (("", 4, 1), ("qwen3-4b", 4, 1),
                                 ("qwen3-4b", 3, 0), ("gemma2-9b", 4, 1))
             for pol in ("lcmp", "matchrdma")]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    assert bat.num_cells == len(specs)
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(np.asarray(a.final.fct_us),
                              np.asarray(b.final.fct_us)), b.spec
        assert np.array_equal(np.asarray(a.final.done),
                              np.asarray(b.final.done)), b.spec
        assert np.array_equal(np.asarray(a.final.flow_path),
                              np.asarray(b.final.flow_path)), b.spec


# --------------------------------------------------------- matchrdma policy
def test_matchrdma_picks_best_matched_rate():
    import jax.numpy as jnp

    from repro.core import baselines as bl
    fids = jnp.arange(1, 65, dtype=jnp.uint32)
    avail = jnp.array([10, 500, 40], jnp.int32)
    valid = jnp.array([True, True, True])
    assert (np.asarray(bl.matchrdma(fids, avail, valid)) == 1).all()
    # an invalid candidate never wins, however fat its matched rate
    choice = np.asarray(bl.matchrdma(
        fids, avail, jnp.array([True, False, True])))
    assert (choice == 2).all()
    # no valid candidate -> -1 (engine drops the flow)
    assert (np.asarray(bl.matchrdma(
        fids, avail, jnp.zeros(3, bool))) == -1).all()
    # ties break by flow-id hash rotation: deterministic, and spread
    # across the tied candidates rather than herding on index 0
    tied = np.asarray(bl.matchrdma(
        fids, jnp.array([7, 7, 7], jnp.int32), valid))
    assert len(np.unique(tied)) > 1
    np.testing.assert_array_equal(tied, np.asarray(bl.matchrdma(
        fids, jnp.array([7, 7, 7], jnp.int32), valid)))


# ------------------------------------------------ iteration metrics (unit)
def _tiny_plan(n_iters=2, nb=2, period=1000):
    R = n_iters * nb
    return CosimPlan(
        model="m", cell="train_4k", n_iters=n_iters, n_buckets=nb,
        pods=2, period_us=period, tokens_per_iter=1, param_count=1,
        compressed=True,
        arrival_us=np.array([i * period + 100 * b for i in range(n_iters)
                             for b in range(nb)], np.int64),
        size_bytes=np.full(R, 1e3), pair_id=np.zeros(R, np.int32),
        flow_id=np.arange(1, R + 1, dtype=np.uint32),
        iter_of=np.repeat(np.arange(n_iters, dtype=np.int32), nb),
        bucket_of=np.tile(np.arange(nb, dtype=np.int32), n_iters),
        phase_of=np.zeros(R, np.int8))


def _fake_run(plan, done, fct_us, paths=None):
    R = plan.num_rows
    flows = SimpleNamespace(arrival_us=plan.arrival_us,
                            cosim_of=np.arange(R, dtype=np.int32))
    final = SimpleNamespace(done=np.asarray(done, bool),
                            fct_us=np.asarray(fct_us, np.float64),
                            flow_path=np.asarray(
                                paths if paths is not None
                                else np.zeros(R, np.int32)))
    return flows, final


def test_iteration_stats_barrier_semantics():
    """An iteration's makespan is its straggler bucket's WALL completion
    minus the iteration start (late-arriving fast buckets still gate);
    one undelivered bucket voids the whole iteration."""
    plan = _tiny_plan()
    flows, final = _fake_run(plan, done=[True, True, True, False],
                             fct_us=[50.0, 200.0, 60.0, 1.0])
    it = iteration_stats(plan, flows, final)
    # iter 0: max(0+50, 100+200) - 0 = 300 us
    np.testing.assert_allclose(it.makespan_ms[0], 0.3)
    assert np.isnan(it.makespan_ms[1])
    assert it.iters_done == 1 and it.iters_total == 2
    assert it.completion_rate == 0.5


def test_pct_strict_charges_incomplete_iterations():
    """The ordering metric counts a dropped iteration as +inf, never
    excludes it — the policy that strands a step cannot win the
    percentile by survivorship."""
    plan = _tiny_plan()
    flows, final = _fake_run(plan, done=[True, True, True, False],
                             fct_us=[50.0, 200.0, 60.0, 1.0])
    it = iteration_stats(plan, flows, final)
    assert it.pct_strict(99) == np.inf
    assert np.isfinite(it.pct_strict(1))
    assert np.isclose(it.pct(50), 0.3)       # lenient pct: complete only
    flows2, final2 = _fake_run(plan, [False] * 4, [0.0] * 4)
    none_done = iteration_stats(plan, flows2, final2)
    assert none_done.pct_strict(50) == np.inf      # inf, never NaN


def test_straggler_attribution():
    """The route carrying each iteration's slowest bucket is charged the
    straggle; undelivered buckets dominate with +inf."""
    plan = _tiny_plan()
    flows, final = _fake_run(plan, done=[True, True, True, False],
                             fct_us=[50.0, 200.0, 60.0, 1.0],
                             paths=[7, 9, 7, 9])
    routes = straggler_routes(plan, flows, final)
    assert routes[9]["stragglers"] == 2        # both iterations
    assert routes[7]["stragglers"] == 0
    assert routes[9]["max_ms"] == np.inf
    assert routes[7]["buckets"] == 2


# ---------------------------------------- telemetry feedback loop (closing)
@pytest.fixture
def fresh_telemetry():
    lc._TELEMETRY.reset()
    yield lc._TELEMETRY
    lc._TELEMETRY.reset()


def test_feed_route_telemetry_demotes_slow_route(fresh_telemetry,
                                                 monkeypatch):
    """The closed loop: replaying a run where one simulated route
    persistently straggles raises that route's congestion score until
    ``schedule_buckets`` stops placing buckets on it — demotion driven
    by measured (simulated) times, not synthetic wall clocks. C_PATH is
    flattened so the (255-capped) congestion term decides the kept set
    — the equal-cost parallel-haul case; see the dist_unit twin for
    why the stock static spread cannot be out-voted."""
    monkeypatch.setattr(lc, "C_PATH", np.zeros_like(lc.C_PATH))
    tm = fresh_telemetry
    n_iters, nb = 12, 3
    plan = _tiny_plan(n_iters=n_iters, nb=nb, period=2000)
    # bucket b of every iteration lands on global path 40+b; path 41
    # (telemetry slot 1) is persistently slow, the rest are quick
    paths = np.tile(np.array([40, 41, 42]), n_iters)
    fct = np.where(paths == 41, 900e3, 50e3)
    flows, final = _fake_run(plan, done=np.ones(plan.num_rows, bool),
                             fct_us=fct, paths=paths)
    slot = {40: 0, 41: 1, 42: 2}
    before = tm.cong_scores().copy()
    feed_route_telemetry(plan, flows, final, tm, path_slot=slot)
    after = tm.cong_scores()
    assert after[1] > before[1]
    assert after[1] > max(after[0], after[2])
    ids = lc._fmix32_host(np.arange(64, dtype=np.uint32))
    assert 1 not in set(lc.schedule_buckets(ids).tolist())


def test_feed_route_telemetry_undone_buckets_look_slow(fresh_telemetry):
    """A route whose buckets never deliver registers at the 2x-period
    horizon time — persistently failing routes must look slow, not
    invisible to the scheduler."""
    tm = fresh_telemetry
    plan = _tiny_plan(n_iters=8, nb=2, period=200_000)
    paths = np.tile(np.array([40, 41]), 8)
    done = paths != 41                           # route 41 black-holes
    flows, final = _fake_run(plan, done=done,
                             fct_us=np.full(plan.num_rows, 50e3),
                             paths=paths)
    feed_route_telemetry(plan, flows, final, tm, path_slot={40: 0, 41: 1})
    assert tm.cong_scores()[1] > tm.cong_scores()[0]


def test_pair_path_slots_maps_candidates():
    scen, table = build_world(TOP)
    pid = table.pair_index()[scen.main_pair]
    slots = pair_path_slots(table, pid)
    assert len(slots) == int(table.pair_ncand[pid])
    for g, k in slots.items():
        assert int(table.pair_cand[pid, k]) == g
