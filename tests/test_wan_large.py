"""Large-scale WAN subsystem contracts: wan2000 generator invariants,
per-pair traffic dosing accuracy (the under-dosing bugfix), the
max_flows truncation error, vectorized arrival bucketing bit-identity,
fg/bg metrics, and sweep bit-for-bit equality over the pairs/bg_load
axes."""
import dataclasses
from collections import deque

import numpy as np
import pytest

from repro.netsim import fluid, metrics, paths, scenarios, sweep, topo
from repro.netsim.engine import SimConfig
from repro.netsim.experiment import ExpSpec, build_world, make_flows
from repro.traffic import cdf as cdfmod
from repro.traffic.gen import FlowSet, dose_bases, generate, pair_dose_basis

WAN = "wan2000:dcs=24,segs=2,chords=12"
WAN_SMALL = "wan2000:dcs=8,segs=2,chords=4"


# ------------------------------------------------- generator invariants
def _connected(t: topo.Topology) -> bool:
    adj = {}
    for s, d, _, _ in t.links:
        adj.setdefault(s, []).append(d)
    seen, q = {0}, deque([0])
    while q:
        for nb in adj.get(q.popleft(), []):
            if nb not in seen:
                seen.add(nb)
                q.append(nb)
    return len(seen) == t.num_nodes


@pytest.mark.parametrize("spec_str,segs", [(WAN, 2), (WAN_SMALL, 2),
                                           ("wan2000:dcs=20,segs=3", 3)])
def test_wan2000_generator_invariants(spec_str, segs):
    """Connected; every advertised pair has m in [2,8] first-hop-distinct
    candidates; every link's capacity and per-segment delay come from the
    declared hardware classes; segment nodes are never endpoints."""
    scen, table = build_world(spec_str)
    t = scen.topology
    assert _connected(t)
    dcs = int(spec_str.split("dcs=")[1].split(",")[0])
    # advertised pairs are DC pairs only, all multi-path, all within m<=8
    assert len(table.pair_src) == len(scen.traffic_pairs) > 0
    assert all(s < dcs and d < dcs for s, d in scen.traffic_pairs)
    assert (table.pair_ncand >= 2).all() and (table.pair_ncand <= 8).all()
    for i in range(len(table.pair_src)):
        cands = table.pair_cand[i][: table.pair_ncand[i]]
        firsts = table.path_first[cands]
        assert len(set(firsts.tolist())) == len(cands)
    # declared classes (caps per haul, delay split across segments)
    seg_delays = {d // segs for d in topo.WAN_DELAY_CLASSES_US}
    for _, _, cap, dl in t.links:
        assert cap in topo.WAN_CAP_CLASSES
        assert dl in seg_delays
    # deterministic under the seed
    again = scenarios.get(spec_str)
    assert again.topology.links == t.links
    assert again.traffic_pairs == scen.traffic_pairs


def test_wan2000_main_pair_is_heterogeneous_and_schedules_hit_it():
    """The designated main pair carries the testbed-style fast-fat /
    slow-thin mix, and the optional degrade/fail schedules target the
    fattest haul's first span (both directions for degrade)."""
    scen, table = build_world(WAN)
    m = table.pair_index()[scen.main_pair]
    caps = table.path_cap[table.pair_cand[m, : table.pair_ncand[m]]]
    assert caps.max() >= 200 and caps.min() <= 40
    w = topo.wan_2000km(dcs=24, segs=2, chords=12)
    deg = scenarios.get(f"{WAN},deg_ms=50,deg_factor=0.3")
    assert deg.degrade_sched == ((w.main_haul_links[0], 50_000, 0.3),
                                 (w.main_haul_links[0] + 1, 50_000, 0.3))
    fail = scenarios.get(f"{WAN},fail_ms=80")
    assert fail.fail_sched == ((w.main_haul_links[0], 80_000),)
    # schedule links are the fattest (200G) haul's first span
    s, d, cap, _ = deg.topology.links[w.main_haul_links[0]]
    assert (s, cap) == (0, 200)


# --------------------------------------------------- per-pair dosing fix
@pytest.mark.parametrize("topology", [WAN, "bso13"])
def test_per_pair_dosing_property(topology):
    """Each pair's realized byte-rate tracks ITS OWN target (the pre-fix
    generator dosed everything off one global min first-hop capacity —
    per-pair errors were then systematic, not sampling noise)."""
    scen, table = build_world(topology)
    pids = [i for i in range(len(table.pair_src)) if table.pair_ncand[i] > 0]
    fs = generate(table, cdfmod.WORKLOADS["websearch"], 0.4,
                  duration_us=2_000_000, pair_ids=pids, seed=3,
                  cap_scale=0.0625, max_flows=500_000)
    assert fs.dosing_error() < 0.05          # aggregate within 5%
    # per-pair: targets really differ (heterogeneous bottleneck classes)
    assert len(np.unique(fs.dose_target)) > 1
    mean = cdfmod.WORKLOADS["websearch"].mean()
    bases = dose_bases(table, pids)
    byte_err = []
    for (p, tgt, real), base in zip(
            zip(fs.dose_pair, fs.dose_target, fs.dose_real), bases):
        # target = load x the pair's OWN (sharing-split) basis
        assert np.isclose(tgt, 0.4 * base * 125.0 * 0.0625)
        n = int((fs.pair_id == p).sum())
        assert n > 0
        # the arrival-count rate is Poisson-tight per pair — the check
        # that catches both truncation and misallocated rate; the
        # byte-rate on top inherits heavy-tailed size noise (per-draw
        # CV >> 1), so it only gets distribution-level bounds below
        lam = tgt / mean
        assert abs(n / 2e6 - lam) / lam < 8.0 / np.sqrt(lam * 2e6)
        byte_err.append(abs(real - tgt) / tgt)
    byte_err = np.array(byte_err)
    assert np.median(byte_err) < 0.35
    assert byte_err.max() < 1.5


def test_generate_raises_instead_of_silently_truncating():
    """The pre-fix behavior cut the END of the arrival window when the
    Poisson draw hit max_flows — less offered load than requested, no
    signal. Both the legacy single-pair path and the multi-pair path
    must raise a clear, actionable error instead."""
    scen, table = build_world("testbed8")
    main = table.pair_index()[scen.main_pair]
    with pytest.raises(ValueError, match="max_flows"):
        generate(table, cdfmod.WORKLOADS["websearch"], 0.8, 1_000_000,
                 pair_ids=[main], cap_scale=0.125, max_flows=500)
    scen2, table2 = build_world(WAN)
    with pytest.raises(ValueError, match="max_flows"):
        generate(table2, cdfmod.WORKLOADS["websearch"], 0.5, 1_000_000,
                 seed=1, cap_scale=0.0625, max_flows=1_000)


def test_single_pair_generation_bit_stable():
    """Regression pin: the single-foreground-pair draw sequence is the
    pre-PR one (tuned acceptance tests and benchmark history depend on
    these exact flow tables)."""
    scen, table = build_world("testbed8")
    main = table.pair_index()[scen.main_pair]
    fs = generate(table, cdfmod.WORKLOADS["websearch"], 0.3, 300_000,
                  pair_ids=[main], seed=0, cap_scale=0.125)
    assert fs.num_flows == 1389
    assert fs.arrival_us[:3].tolist() == [142, 356, 360]
    assert fs.flow_id[:3].tolist() == [2132099435, 1045437217, 929310042]
    assert fs.foreground.all()
    assert np.isclose(fs.dose_target[0],
                      0.3 * pair_dose_basis(table, main) * 125.0 * 0.125)


def test_bg_cross_traffic_masks_and_doses():
    """bg_pair_ids dose at bg_load, fg at load; fg_mask separates them;
    dose telemetry covers both sides."""
    scen, table = build_world(WAN_SMALL)
    spec = ExpSpec(topology=WAN_SMALL, load=0.5, bg_load=0.1, seed=2,
                   duration_us=400_000, cap_scale=0.0625)
    fs = make_flows(spec, scen, table)
    main = table.pair_index()[scen.main_pair]
    fg = fs.foreground
    assert fg.any() and (~fg).any()
    assert (fs.pair_id[fg] == main).all()
    assert (fs.pair_id[~fg] != main).all()
    by = dict(zip(fs.dose_pair.tolist(), fs.dose_target.tolist()))
    # sharing splits within each dose group: fg keeps its full class,
    # bg pairs divide shared first hops among themselves
    bg_ids = [p for p in fs.dose_pair.tolist() if p != main]
    assert np.isclose(by[main],
                      0.5 * pair_dose_basis(table, main) * 125.0 * 0.0625)
    for p, base in zip(bg_ids, dose_bases(table, bg_ids)):
        assert np.isclose(by[p], 0.1 * base * 125.0 * 0.0625)


# ------------------------------------------------ arrival bucketing fix
def _bucket_reference(flows, cfg):
    """The pre-PR per-flow Python loop, kept as the oracle."""
    T = cfg.num_steps
    step = np.minimum(flows.arrival_us // cfg.dt_us, T - 1).astype(np.int64)
    counts = np.bincount(step, minlength=T)
    A = max(int(counts.max()), 1)
    arrivals = np.full((T, A), -1, np.int32)
    slot = np.zeros(T, np.int64)
    for i, s in enumerate(step):
        arrivals[s, slot[s]] = i
        slot[s] += 1
    return arrivals


@pytest.mark.parametrize("seed", [0, 1])
def test_vectorized_arrival_bucketing_bit_identical(seed):
    """engine.build()'s argsort/cumcount bucketing == the old O(F) loop,
    including same-step herd batches and the clamped last step."""
    t = topo.parallel_paths(caps=(100, 100), delays_us=(5000, 5000))
    table = paths.build_path_table(t, [(0, 3)])
    fluid.attach_link_caps(table, t)
    rng = np.random.default_rng(seed)
    F = 5000
    cfg = SimConfig(horizon_us=100_000)
    # duplicates + out-of-horizon arrivals exercise clamp and herd paths
    arr = np.sort(rng.integers(0, 150_000, F))
    flows = FlowSet(arrival_us=arr.astype(np.int64),
                    size_bytes=np.full(F, 1e4),
                    pair_id=np.zeros(F, np.int32),
                    flow_id=rng.integers(1, 1 << 32, F, dtype=np.uint32))
    arrs, _ = fluid.build(table, flows, cfg)
    assert np.array_equal(np.asarray(arrs.arrivals),
                          _bucket_reference(flows, cfg))


# ----------------------------------------------------- fg/bg metrics
def test_fct_stats_mask_and_completion_rate():
    from types import SimpleNamespace
    t = topo.parallel_paths(caps=(100,), delays_us=(5000,))
    table = paths.build_path_table(t, [(0, 2)])
    flows = FlowSet(arrival_us=np.zeros(4, np.int64),
                    size_bytes=np.full(4, 1e6),
                    pair_id=np.zeros(4, np.int32),
                    flow_id=np.arange(1, 5, dtype=np.uint32),
                    fg_mask=np.array([True, True, False, False]))
    final = SimpleNamespace(done=np.array([True, False, True, True]),
                            fct_us=np.array([2e4, 0.0, 4e4, 8e4], np.float32))
    cfg = SimConfig(cap_scale=1.0)
    fg, bg = metrics.fg_bg_stats(final, table, flows, cfg)
    assert (fg.completed, fg.offered) == (1, 2)
    assert (bg.completed, bg.offered) == (2, 2)
    assert fg.completion_rate == 0.5 and bg.completion_rate == 1.0
    per = metrics.per_pair_stats(final, table, flows, cfg)
    assert list(per) == [0] and per[0].completed == 3
    # all-foreground sets report bg=None and fg == overall
    all_fg = dataclasses.replace(flows, fg_mask=None)
    fg2, bg2 = metrics.fg_bg_stats(final, table, all_fg, cfg)
    assert bg2 is None and fg2.completed == 3 and fg2.offered == 4


# -------------------------------------------- sweep over the new axes
def test_sweep_pairs_bg_axes_bit_for_bit():
    """pairs/bg_load are dynamic axes: the whole grid shares traces per
    scenario and reproduces the sequential loop exactly, fg/bg splits
    included."""
    specs = [ExpSpec(topology=WAN_SMALL, load=0.4, bg_load=bg, policy=pol,
                     pairs=pairs, duration_us=60_000, cap_scale=0.0625,
                     seed=1)
             for bg, pairs in ((0.0, "main"), (0.15, "main"), (0.0, "all"))
             for pol in ("lcmp", "ecmp")]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    assert bat.num_cells == len(specs)
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.done, b.final.done), b.spec
        assert np.array_equal(a.stats.slowdown, b.stats.slowdown), b.spec
        assert a.stats_fg.completed == b.stats_fg.completed
        assert (a.stats_bg is None) == (b.stats_bg is None)
        if a.stats_bg is not None:
            assert np.array_equal(a.stats_bg.slowdown, b.stats_bg.slowdown)
            # fg + bg partition the offered flows
            assert (b.stats_fg.offered + b.stats_bg.offered
                    == b.stats.offered)
