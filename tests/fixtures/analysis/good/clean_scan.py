"""Good: every pattern the checkers look for, done correctly — static
casts and branches, wrapped ring slots with a capacity guard, explicit
scatter mode, dtype'd np constructor, a fully classified ExpSpec."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

HIST = 32
MAX_DELAY = 8

if MAX_DELAY >= HIST:
    raise ValueError("history ring too small for the max delay")


@dataclasses.dataclass(frozen=True)
class ExpSpec:
    engine: str = "fluid"
    load: float = 0.3
    topology: str = "testbed8"


AXES_STATIC = ("engine",)
AXES_DYNAMIC = ("load",)
AXES_EXEMPT = {"topology": "trace key via world shapes, not spec_to_cfg"}


def spec_to_cfg(spec, scen):
    return {"engine": spec.engine}


def make_step(cfg: dict):
    scale = float(cfg["scale"])          # cast of a static: fine

    def step(carry, t):
        hist_q = carry
        slot = t % HIST
        hist_q = hist_q.at[:, slot].set(scale, mode="promise_in_bounds")
        if cfg["twice"]:                 # branch on a static: fine
            hist_q = hist_q + np.float32(1.0)
        bias = np.zeros(4, np.float32)   # dtype'd np constructor: fine
        return hist_q, bias.sum()

    return step


def run(hist_q, cfg: dict):
    step = make_step(cfg)
    out, _ = jax.lax.scan(step, hist_q, jnp.arange(8))
    return out
