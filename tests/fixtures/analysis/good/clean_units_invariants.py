"""Good: explicit unit conversions (every cross-unit product goes
through a literal conversion factor) and a complete sanitizer registry
covering the one field the scan mutates."""
import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SimState:
    remaining: jnp.ndarray


def _check_bytes(st):
    return (st.remaining >= 0).all()


INVARIANTS = {"byte_conservation": _check_bytes}
INVARIANT_COVERAGE = {"remaining": ("byte_conservation",)}
COVERAGE_EXEMPT = {}


def wait_total_us(queue_bytes, rate_gbps, budget_ms):
    drain_us = queue_bytes / (rate_gbps * 125.0)   # gbps -> bytes/us
    return drain_us + budget_ms * 1000.0           # ms -> us


def step(st, t):
    return dataclasses.replace(st, remaining=st.remaining - 1.0), None


def run(st):
    out, _ = jax.lax.scan(step, st, jnp.arange(4))
    return out
