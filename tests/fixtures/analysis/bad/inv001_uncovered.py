"""Bad: a SimState field is mutated inside the scan body but the
sanitizer registries cover neither it nor an exemption."""
import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class SimState:
    remaining: jnp.ndarray


def step(st, t):
    st = dataclasses.replace(st, remaining=st.remaining - 1.0)
    return st, None


def run(st):
    out, _ = jax.lax.scan(step, st, jnp.arange(8))
    return out
