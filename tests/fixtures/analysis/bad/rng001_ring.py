"""Bad: history-ring read without a `% HIST` wrap (guard present, so
only RNG001 fires — the capacity guard alone does not make unwrapped
offsets safe)."""
HIST = 64
MAX_DELAY = 8

if MAX_DELAY >= HIST:
    raise ValueError("history ring too small for the max delay")


def read_back(hist_q, t, delay):
    slot = t - delay
    return hist_q[:, slot]
