"""Bad: an ExpSpec field (`extra_knob`) is in no AXES_* table."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ExpSpec:
    engine: str = "fluid"
    load: float = 0.3
    extra_knob: int = 0


AXES_STATIC = ("engine",)
AXES_DYNAMIC = ("load",)
AXES_EXEMPT = {}


def spec_to_cfg(spec, scen):
    return {"engine": spec.engine}
