"""Bad: `load` is declared dynamic but spec_to_cfg reads it, so it
would enter the trace key and recompile every sweep cell."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ExpSpec:
    engine: str = "fluid"
    load: float = 0.3


AXES_STATIC = ("engine",)
AXES_DYNAMIC = ("load",)
AXES_EXEMPT = {}


def spec_to_cfg(spec, scen):
    return {"engine": spec.engine, "load": spec.load}
