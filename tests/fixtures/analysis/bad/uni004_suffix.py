"""Bad: binds a millisecond value to a *_us-named variable — the
target's suffix contradicts the unit of the assigned expression."""


def to_micro(span_ms):
    span_us = span_ms
    return span_us
