"""Bad: compares microseconds against milliseconds without converting
— same dimension, wrong scale (the classic silent 1000x)."""


def deadline_hit(now_us, budget_ms):
    return now_us > budget_ms
