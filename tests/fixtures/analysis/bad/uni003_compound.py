"""Bad: subtracts a rate x time product (gbps*us) straight from bytes
— the compound quantity needs the gbps -> bytes/us conversion first."""


def backlog(q_bytes, rate_gbps, dt_us):
    return q_bytes - rate_gbps * dt_us
