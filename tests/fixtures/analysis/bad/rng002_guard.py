"""Bad: rings written with wrapped slots but no build-time capacity
guard anywhere — wraps are only sound when offsets are validated."""
HIST = 64


def write(hist_c, t, val):
    return hist_c.at[:, t % HIST].set(val, mode="promise_in_bounds")
