"""Bad: adds bytes to microseconds — incompatible dimensions under the
*_us/*_bytes naming convention."""


def total_cost(q_bytes, wait_us):
    return q_bytes + wait_us
