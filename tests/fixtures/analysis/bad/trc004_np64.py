"""Bad: dtype-less np constructor (float64 default) in jitted code."""
import jax
import numpy as np


def run(x):
    return x + np.ones(4)


runner = jax.jit(run)
