"""Bad: sanitizer registry rot — a coverage key that is not a state
field (a field rename left the registry behind; the invariant name it
references is real)."""
import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass
class SimState:
    q_depth: jnp.ndarray


def _check_queue(st):
    return (st.q_depth >= 0).all()


INVARIANTS = {"queue_nonneg": _check_queue}
INVARIANT_COVERAGE = {"q_deth": ("queue_nonneg",)}
