"""Bad: `cc` is declared static but spec_to_cfg never reads it, so
cells differing only in `cc` would share one compiled config."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ExpSpec:
    engine: str = "fluid"
    cc: str = "dcqcn"


AXES_STATIC = ("engine", "cc")
AXES_DYNAMIC = ()
AXES_EXEMPT = {}


def spec_to_cfg(spec, scen):
    return {"engine": spec.engine}
