"""Bad: float() applied to a traced value inside a jitted function."""
import jax


def run(x):
    return float(x) + 1.0


runner = jax.jit(run)
