"""Bad: Python `if` on a traced value inside a jitted function."""
import jax


def run(x):
    if x.sum() > 0:
        return x * 2.0
    return x


runner = jax.jit(run)
