"""Bad: traced-index scatter without explicit mode= inside a scan body."""
import jax
import jax.numpy as jnp


def make_step(cfg: dict):
    def step(carry, t):
        hist = carry
        hist = hist.at[t % 16].set(1.0)
        return hist, ()
    return step


def run(hist, cfg: dict):
    step = make_step(cfg)
    out, _ = jax.lax.scan(step, hist, jnp.arange(8))
    return out
