"""Unit test for the per-flow delayed-feedback gate in the fluid sim's
CC update (regression: the gate used to be ``t > rtt_steps`` — global —
so a flow arriving late immediately read congestion history recorded
*before* it was routed)."""
import jax.numpy as jnp
import numpy as np

from repro.core.cong import CongState
from repro.netsim import fluid


def _state_two_flows(t, rtt):
    """Two identical line-rate flows on link 0; flow 0 routed long ago,
    flow 1 routed just now. The history ring carries heavy congestion at
    the delayed-read slot (t - rtt)."""
    hist_q = np.zeros((1, fluid.HIST), np.float32)
    hist_q[0, (t - rtt) % fluid.HIST] = 1e9
    z = jnp.zeros((2,), jnp.float32)
    return fluid.SimState(
        flow_path=jnp.zeros(2, jnp.int32),
        remaining=jnp.ones(2, jnp.float32) * 1e9,
        rate=jnp.full((2,), 100.0, jnp.float32),
        active=jnp.ones(2, bool),
        done=jnp.zeros(2, bool),
        fct_us=z,
        extra_wait=z,
        rtt_steps=jnp.full((2,), rtt, jnp.int32),
        route_step=jnp.asarray([0, t - 1], jnp.int32),
        route_nonce=jnp.zeros(2, jnp.int32),
        last_dec=jnp.full((2,), -(1 << 20), jnp.int32),
        cc_alpha=z,
        cc_target=jnp.full((2,), 100.0, jnp.float32),
        prev_delay=z,
        q_bytes=jnp.zeros((1,), jnp.float32),
        hist_q=jnp.asarray(hist_q),
        hist_u=jnp.zeros((1, fluid.HIST), jnp.float32),
        hist_c=jnp.zeros((1, fluid.HIST), jnp.int32),
        u_ewma=jnp.zeros((1,), jnp.float32),
        link_alive=jnp.ones((1,), bool),
        serv_bytes=jnp.zeros((1,), jnp.float32),
        cong=CongState.init(1),
        c_cong=jnp.zeros((1,), jnp.int32),
        c_path=jnp.zeros((1,), jnp.int32),
        redte_w=jnp.ones((1, 1), jnp.int32),
    )


def _arrays():
    return fluid.SimArrays(
        link_cap=jnp.asarray([125.0], jnp.float32),
        link_cap_gbps=None, path_links=None, path_prop=None,
        path_cap=jnp.asarray([100.0], jnp.float32),
        path_cap_gbps=None, path_first=None, pair_cand=None,
        arrivals=None, f_arr_us=None, f_size=None, f_pair=None,
        f_id=jnp.asarray([1, 2], jnp.uint32), tables=None)


def test_feedback_gated_on_flows_own_route_step():
    cfg = fluid.SimConfig(cc="dcqcn")
    t, rtt = 5000, 4
    st = _state_two_flows(t, rtt)
    out = fluid._cc_update(t, st, _arrays(), cfg,
                           path_of_flow=jnp.zeros(2, jnp.int32),
                           links_f=jnp.zeros((2, 1), jnp.int32),
                           links_ok=jnp.ones((2, 1), bool))
    # established flow: sees the RTT-delayed congestion signal -> MD
    assert float(out.rate[0]) < 100.0
    # flow routed one step ago: that history predates its routing; it
    # must NOT react to it (no feedback for its first RTT on the path)
    assert float(out.rate[1]) >= 100.0


def test_feedback_arrives_after_one_rtt_on_own_path():
    cfg = fluid.SimConfig(cc="dcqcn")
    t, rtt = 5000, 4
    st = _state_two_flows(t, rtt)
    # re-route flow 1 exactly rtt+1 steps before t: feedback now exists
    st = __import__("dataclasses").replace(
        st, route_step=jnp.asarray([0, t - rtt - 1], jnp.int32))
    out = fluid._cc_update(t, st, _arrays(), cfg,
                           path_of_flow=jnp.zeros(2, jnp.int32),
                           links_f=jnp.zeros((2, 1), jnp.int32),
                           links_ok=jnp.ones((2, 1), bool))
    assert float(out.rate[1]) < 100.0
