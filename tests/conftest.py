"""Shared test configuration.

The core property tests require ``hypothesis`` (declared in
requirements-dev.txt and installed by CI). Containers that cannot
pip-install at test time fall back to ``tests/_stubs/hypothesis.py`` —
a minimal API-compatible stand-in that runs each property against
boundary examples plus seeded uniform randoms, so the suite still
collects and the properties still execute. Install the real package for
shrinking and coverage-guided generation.
"""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_stubs"))
