"""Sweep-engine contracts: the batched (vmapped) grid must reproduce the
sequential per-cell loop bit-for-bit, grouping must be maximal for
dynamic axes, and the shard_map path must agree across devices."""
import json
import subprocess
import sys

import numpy as np

from repro.netsim import sweep
from repro.netsim.experiment import ExpSpec

_DUR = 60_000   # short horizons keep the suite fast; grid size does the work


def _grid():
    return [ExpSpec(topology="testbed8", load=load, policy=pol,
                    duration_us=_DUR, seed=seed)
            for load in (0.3, 0.5)
            for pol in ("lcmp", "ecmp", "redte")
            for seed in (0, 1)]


def test_batched_sweep_matches_sequential_bit_for_bit():
    """The acceptance bar: one vmapped call == the ExpSpec loop, exactly.
    Covers the dynamic-policy dispatch (3 policies), flow-count padding
    (2 loads) and seed variation in a single group."""
    specs = _grid()
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    # policy and seed are dynamic axes sharing a trace; the load axis may
    # chunk on the flow-count padding budget — never per-cell re-tracing
    assert bat.num_groups <= 2
    assert bat.num_cells == len(specs)
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.done, b.final.done), b.spec
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.flow_path, b.final.flow_path), b.spec
        assert np.array_equal(a.stats.slowdown, b.stats.slowdown), b.spec
        assert np.array_equal(a.util, b.util), b.spec
        assert a.stats.completed == b.stats.completed


def test_map_batch_mode_matches_sequential_bit_for_bit():
    """The compute-bound strategy (lax.map over cells in one trace) is
    exactly equivalent too."""
    specs = _grid()[:4]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs, batch_mode="map")
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.done, b.final.done), b.spec


def test_policy_and_seed_axes_share_one_trace():
    """A same-load grid (near-equal flow counts) is exactly one compiled
    group — the whole policy x seed plane in a single XLA computation."""
    specs = [ExpSpec(topology="testbed8", load=0.3, policy=pol,
                     duration_us=_DUR, seed=seed)
             for pol in ("lcmp", "ecmp", "ucmp", "wcmp") for seed in (0, 1)]
    rep = sweep.run_sweep(specs)
    assert rep.num_groups == 1
    assert rep.group_cells == [8]


def test_sweep_groups_by_static_axes():
    """cc and parameter overrides force separate traces; loads don't."""
    from repro.core.select import SelectParams
    specs = [ExpSpec(topology="testbed8", load=0.3, cc="dcqcn", duration_us=_DUR),
             ExpSpec(topology="testbed8", load=0.5, cc="dcqcn", duration_us=_DUR),
             ExpSpec(topology="testbed8", load=0.3, cc="dctcp", duration_us=_DUR),
             ExpSpec(topology="testbed8", load=0.3, cc="dcqcn", duration_us=_DUR,
                     select=SelectParams(alpha=1, beta=1))]
    keys = [sweep.static_key(s) for s in specs]
    assert keys[0] == keys[1]
    assert keys[0] != keys[2]
    assert keys[0] != keys[3]


def test_sweep_mixed_scenarios_and_workloads():
    """Cells from different scenarios coexist in one call (separate
    groups) and workload variation stays inside a group."""
    specs = [ExpSpec(topology="testbed8", workload=wl, load=0.3,
                     policy="lcmp", duration_us=_DUR)
             for wl in ("websearch", "fbhdp")]
    specs += [ExpSpec(topology="parallel:n=3,cap=40", load=0.3,
                      policy="ecmp", duration_us=_DUR)]
    rep = sweep.run_sweep(specs)
    assert rep.num_groups == 2 and rep.num_cells == 3
    for res in rep.results:
        assert res.stats.completed > 0
        assert np.isfinite(res.stats.p50)


def test_sweep_staleness_axes_bit_for_bit():
    """The new signal-plane axes: sig_delay_scale/ctrl_period_us are
    static (trace-level) axes — each value pair is its own group, the
    policy axis stays dynamic inside, and the batched run reproduces the
    sequential loop exactly, live c_path table included."""
    specs = [ExpSpec(topology="staleness:deg_ms=20", load=0.3, policy=pol,
                     duration_us=_DUR, sig_delay_scale=sds,
                     ctrl_period_us=25_000)
             for sds in (0.0, 2.0) for pol in ("lcmp", "ecmp")]
    seq = sweep.run_sweep(specs, sequential=True)
    bat = sweep.run_sweep(specs)
    assert bat.num_groups == 2           # one trace per delay scale
    for a, b in zip(seq.results, bat.results):
        assert np.array_equal(a.final.fct_us, b.final.fct_us), b.spec
        assert np.array_equal(a.final.done, b.final.done), b.spec
        assert np.array_equal(a.final.c_path, b.final.c_path), b.spec
        assert np.array_equal(a.util, b.util), b.spec


def test_failover_scenario_matches_legacy_fail_link():
    """The scenario schedule path must reproduce the legacy
    cfg.fail_link single-event injection exactly."""
    import dataclasses
    from repro.netsim import fluid
    from repro.netsim.experiment import build_experiment

    legacy_spec = ExpSpec(topology="testbed8", load=0.3, policy="lcmp",
                          duration_us=120_000, seed=5)
    _, table, flows, cfg = build_experiment(legacy_spec)
    cfg = dataclasses.replace(cfg, fail_link=12, fail_at_us=40_000)
    arrs, st = fluid.build(table, flows, cfg)
    legacy = fluid.run(arrs, st, cfg)

    scen_spec = dataclasses.replace(
        legacy_spec, topology="testbed8_failover:fail_ms=40,link=12")
    _, table2, flows2, cfg2 = build_experiment(scen_spec)
    assert flows2.num_flows == flows.num_flows   # same world, same traffic
    arrs2, st2 = fluid.build(table2, flows2, cfg2)
    final = fluid.run(arrs2, st2, cfg2)
    assert np.array_equal(np.asarray(legacy.done), np.asarray(final.done))
    assert np.array_equal(np.asarray(legacy.fct_us), np.asarray(final.fct_us))


def test_degradation_shifts_new_placements():
    """Silent capacity loss: flows stay pinned (no reroute), the run still
    completes, and the degraded link serves measurably fewer bytes than
    the healthy baseline."""
    import dataclasses
    from repro.netsim import fluid
    from repro.netsim.experiment import build_experiment

    spec = ExpSpec(topology="parallel:n=2,cap=100", load=0.5, policy="ecmp",
                   duration_us=150_000, seed=3)
    _, table, flows, cfg = build_experiment(spec)
    arrs, st = fluid.build(table, flows, cfg)
    healthy = fluid.run(arrs, st, cfg)

    first = int(table.path_first[0])
    cfg_d = dataclasses.replace(cfg, degrade_sched=((first, 30_000, 0.2),))
    arrs_d, st_d = fluid.build(table, flows, cfg_d)
    degraded = fluid.run(arrs_d, st_d, cfg_d)

    assert np.asarray(degraded.done).mean() > 0.9
    assert (float(degraded.serv_bytes[first])
            < 0.8 * float(healthy.serv_bytes[first]))
    # silent: placements never move off the degraded path
    assert np.array_equal(np.asarray(healthy.flow_path)[np.asarray(healthy.done)],
                          np.asarray(degraded.flow_path)[np.asarray(healthy.done)])


_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
from repro.netsim import sweep
from repro.netsim.experiment import ExpSpec

specs = [ExpSpec(topology="testbed8", load=0.3, policy=p,
                 duration_us=40_000, seed=1)
         for p in ("lcmp", "ecmp", "ucmp")]   # 3 cells pad to 2 devices x 2
seq = sweep.run_sweep(specs, sequential=True)
bat = sweep.run_sweep(specs, use_mesh=True)
same = all(np.array_equal(a.final.fct_us, b.final.fct_us)
           and np.array_equal(a.final.done, b.final.done)
           for a, b in zip(seq.results, bat.results))
print(json.dumps({"same": same, "cells": bat.num_cells}))
"""


def test_shard_map_sweep_matches_sequential():
    """Cell axis sharded over 2 host devices (subprocess — XLA device
    count locks at first init) still reproduces the sequential loop."""
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res == {"same": True, "cells": 3}
