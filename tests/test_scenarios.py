"""Scenario-registry contracts: every named scenario builds a valid
multi-candidate world, parameter strings parse, and the CLI rejects
unknown names with a helpful message (no raw KeyError)."""
import subprocess
import sys

import pytest

from repro.netsim import paths, scenarios, topo


@pytest.mark.parametrize("name", scenarios.names())
def test_registry_builds_valid_path_tables(name):
    """Default-parameter build of every scenario yields a topology whose
    main pair has multiple first-hop-distinct candidates (except the
    deliberately single-path cases) and a structurally valid table."""
    scen = scenarios.get(name)
    t = scen.topology
    table = paths.build_path_table(t, paths.all_pairs(t))
    pidx = table.pair_index()
    main = pidx[scen.main_pair]
    assert table.pair_ncand[main] >= 2, (name, scen.main_pair)
    cands = table.pair_cand[main][: table.pair_ncand[main]]
    firsts = table.path_first[cands]
    assert len(set(firsts.tolist())) == len(cands)   # first-hop distinct
    # per-path attributes consistent with the link arrays
    _, _, cap_a, del_a = t.arrays()
    for p in cands:
        hops = table.path_links[p][table.path_links[p] >= 0]
        assert table.path_prop_us[p] == del_a[hops].sum()
        assert table.path_cap[p] == cap_a[hops].min()
    # schedules reference real links
    for li, _ in scen.fail_sched:
        assert 0 <= li < t.num_links
    for li, _, fac in scen.degrade_sched:
        assert 0 <= li < t.num_links and 0.0 < fac <= 1.0


def test_param_parsing():
    name, params = scenarios.parse("longhaul_mesh:routes=8,segs=3,caps=200+40,lo_ms=5")
    assert name == "longhaul_mesh"
    assert params == {"routes": 8, "segs": 3, "caps": (200, 40), "lo_ms": 5}
    scen = scenarios.get("longhaul_mesh:routes=8,segs=3,caps=200+40")
    assert scen.topology.num_nodes == 2 + 8 * 3
    # 8 first-hop-distinct candidate routes survive enumeration
    table = paths.build_path_table(scen.topology, [scen.main_pair])
    assert table.pair_ncand[0] == 8


def test_unknown_scenario_and_bad_params_raise_helpfully():
    with pytest.raises(ValueError, match="available:"):
        scenarios.get("nope")
    with pytest.raises(ValueError, match="bad scenario parameter"):
        scenarios.get("parallel:n")
    with pytest.raises(ValueError, match="bad parameters"):
        scenarios.get("parallel:bogus_key=3")


def test_jitter_is_asymmetric_and_schedule_preserving():
    scen = scenarios.get("jitter:base=testbed8,frac=0.3,seed=7")
    base = topo.testbed_8dc()
    fwd = {(s, d): dl for s, d, _, dl in scen.topology.links}
    diffs = [abs(fwd[(s, d)] - fwd[(d, s)]) for s, d, _, _ in base.links]
    assert max(diffs) > 0                       # directions diverge
    caps = {(s, d): c for s, d, c, _ in scen.topology.links}
    assert all(caps[(s, d)] == c for s, d, c, _ in base.links)  # caps intact
    # deterministic under the seed
    again = scenarios.get("jitter:base=testbed8,frac=0.3,seed=7")
    assert again.topology.links == scen.topology.links
    # failover base keeps its schedule through the jitter wrapper
    wrapped = scenarios.get("jitter:base=testbed8_failover,frac=0.1")
    assert wrapped.fail_sched == scenarios.get("testbed8_failover").fail_sched


def test_segmented_parallel_structure():
    t = topo.segmented_parallel([100, 40], [10_000, 250_000], segs=3)
    # 2 routes x (3 segments + 1 tail hop), bidirectional
    assert t.num_links == 2 * 2 * 4
    assert t.num_nodes == 2 + 2 * 3
    table = paths.build_path_table(t, [(0, t.num_nodes - 1)])
    assert table.pair_ncand[0] == 2
    assert sorted(table.path_cap[:2].tolist()) == [40, 100]


def test_benchmark_cli_rejects_unknown_suite():
    """Satellite bugfix: `--only` with an unknown name must exit with a
    clear message listing valid suites, not a raw KeyError."""
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--only", "fig99"],
        capture_output=True, text=True, timeout=300)
    assert out.returncode != 0
    err = out.stderr + out.stdout
    assert "KeyError" not in err
    assert "unknown suite" in err and "fig99" in err
    assert "fig5" in err and "kernels" in err   # lists the valid names
