"""reprolint: fixture corpus, CLI contract, wire-format freeze, and the
bit-for-bit regression for the ring-scatter mode= fixes it surfaced."""
import dataclasses
import json
import os
import shutil
import subprocess
import sys

import jax
import pytest

from repro.analysis import CHECKS, CODES, run_checks
from repro.analysis.wire import MANIFEST_REL, build_manifest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIX = os.path.join(REPO, "tests", "fixtures", "analysis")

# bad fixture -> the exact finding code it must raise (and nothing else)
BAD_EXPECT = {
    "trc001_cast.py": "TRC001",
    "trc002_branch.py": "TRC002",
    "trc003_scatter.py": "TRC003",
    "trc004_np64.py": "TRC004",
    "rng001_ring.py": "RNG001",
    "rng002_guard.py": "RNG002",
    "axs001_missing.py": "AXS001",
    "axs002_dynamic_read.py": "AXS002",
    "axs003_static_unread.py": "AXS003",
    "uni001_mix.py": "UNI001",
    "uni002_scale.py": "UNI002",
    "uni003_compound.py": "UNI003",
    "uni004_suffix.py": "UNI004",
    "inv001_uncovered.py": "INV001",
    "inv002_rot.py": "INV002",
}


# ------------------------------------------------------- fixture corpus
@pytest.mark.parametrize("fname,code", sorted(BAD_EXPECT.items()))
def test_bad_fixture_raises_exactly_its_code(fname, code):
    path = os.path.join(FIX, "bad", fname)
    rep = run_checks(os.path.join(FIX, "bad"), files=[path])
    assert sorted({f.code for f in rep.findings}) == [code], rep.findings
    assert len(rep.findings) == 1, rep.findings
    assert all(f.code in CODES for f in rep.findings)


def test_bad_corpus_covers_every_nonwire_code():
    # WIR001/WIR002 are exercised against the real repo below; every
    # other code must have a dedicated bad fixture
    covered = set(BAD_EXPECT.values()) | {"WIR001", "WIR002"}
    assert covered == set(CODES)


def test_good_fixtures_clean():
    good = os.path.join(FIX, "good")
    files = [os.path.join(good, f) for f in sorted(os.listdir(good))
             if f.endswith(".py")]
    rep = run_checks(good, files=files)
    assert rep.ok, rep.findings


def test_full_repo_smoke_clean():
    rep = run_checks(REPO)
    assert rep.ok, "\n".join(f.format() for f in rep.findings)
    assert rep.num_files > 50          # really saw src/ and tests/
    assert not any("fixtures" in p for p in
                   (f.path for f in rep.findings + rep.suppressed))


def test_exemption_comment_suppresses(tmp_path):
    f = tmp_path / "exempt.py"
    f.write_text(
        "import jax\n\n\ndef run(x):\n"
        "    # reprolint: ignore[TRC001] build-time scalar\n"
        "    return float(x)\n\n\nrunner = jax.jit(run)\n")
    rep = run_checks(str(tmp_path), files=[str(f)])
    assert rep.ok
    assert [s.code for s in rep.suppressed] == ["TRC001"]


def test_unknown_check_name_rejected():
    with pytest.raises(ValueError, match="unknown check"):
        run_checks(REPO, checks=["nope"])
    assert set(CHECKS) == {"tracing", "axes", "wire", "rings",
                           "units", "invariants"}


# ------------------------------------------------------------------ CLI
def _cli(args, cwd=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run([sys.executable, "-m", "repro.analysis"] + args,
                          capture_output=True, text=True, env=env, cwd=cwd)


def test_cli_fails_on_seeded_violation_github_format(tmp_path):
    # the CI lint job runs exactly this module; prove it goes red on a
    # seeded violation, with a GitHub annotation naming the code
    shutil.copy(os.path.join(FIX, "bad", "trc001_cast.py"), tmp_path)
    p = _cli(["--root", str(tmp_path), "--format", "github"])
    assert p.returncode == 1, p.stdout + p.stderr
    assert "::error file=trc001_cast.py" in p.stdout
    assert "reprolint TRC001" in p.stdout


def test_cli_json_clean_tree(tmp_path):
    shutil.copytree(os.path.join(FIX, "good"), tmp_path / "tree")
    p = _cli(["--root", str(tmp_path / "tree"), "--format", "json"])
    assert p.returncode == 0, p.stdout + p.stderr
    data = json.loads(p.stdout)
    assert data["ok"] is True and data["findings"] == []


def test_cli_check_subset(tmp_path):
    shutil.copy(os.path.join(FIX, "bad", "trc002_branch.py"), tmp_path)
    p = _cli(["--root", str(tmp_path), "--checks", "rings,axes"])
    assert p.returncode == 0, p.stdout   # tracing not selected -> clean


# ----------------------------------------------------- wire-format freeze
def test_wire_manifest_is_current():
    with open(os.path.join(REPO, MANIFEST_REL), encoding="utf-8") as f:
        frozen = json.load(f)
    assert frozen == build_manifest(REPO), (
        "wire-format manifest is stale — regenerate with "
        "`python -m repro.analysis --write-manifest`")


def test_wire_drift_and_missing_manifest(tmp_path):
    man = build_manifest(REPO)
    tampered = dict(man)
    tampered["sched_families"] = list(man["sched_families"]) + ["bogus"]
    mp = tmp_path / "manifest.json"
    mp.write_text(json.dumps(tampered))
    rep = run_checks(REPO, checks=["wire"], manifest=str(mp))
    assert [f.code for f in rep.findings] == ["WIR001"]
    assert "sched_families" in rep.findings[0].message
    assert "--write-manifest" in rep.findings[0].message

    rep = run_checks(REPO, checks=["wire"],
                     manifest=str(tmp_path / "missing.json"))
    assert [f.code for f in rep.findings] == ["WIR002"]


def test_wire_manifest_freezes_the_advertised_surfaces():
    man = build_manifest(REPO)
    assert man["policy_codes"]["lcmp"] == 0 and man["policy_codes"]["ecmp"] == 2
    assert "const" in man["sched_families"]
    assert "testbed8" in man["scenario_names"]
    assert man["csv_schemas"]["fig5_testbed.csv"][0] == "load"
    assert "rows_us" in man["bench_keys"]["top"]


# ------------------------- ring-scatter mode= fixes (bit-for-bit pin)
@pytest.mark.parametrize("engine_name", ["fluid", "packet"])
def test_ring_scatter_mode_is_bit_identical(engine_name):
    """reprolint TRC003 fixes added mode="promise_in_bounds" to the six
    history-ring scatters. All ring slots are `t % HIST`, in-bounds by
    construction, so the mode change must be a pure no-op: the final
    state under promise_in_bounds must equal the default-mode state
    bit for bit."""
    from repro.netsim import engine as eng
    from repro.netsim import experiment, fluid, packet
    mod = {"fluid": fluid, "packet": packet}[engine_name]
    spec = experiment.ExpSpec(topology="testbed8", load=0.5,
                              engine=engine_name, duration_us=3_000)
    _, table, flows, cfg = experiment.build_experiment(spec)

    def final_state(mode):
        old = eng.RING_SCATTER_MODE
        eng.RING_SCATTER_MODE = mode
        try:
            arrs, st = mod.build(table, flows, cfg)
            # fresh jit wrapper: the mode is baked into the trace, so a
            # cached executable would hide a behavioral difference
            run = jax.jit(mod.run_impl, static_argnames=("cfg",))
            return run(arrs, st, cfg)
        finally:
            eng.RING_SCATTER_MODE = old

    a = final_state("promise_in_bounds")
    b = final_state(None)                 # jax default (FILL_OR_DROP)
    la = jax.tree.leaves(dataclasses.asdict(a))
    lb = jax.tree.leaves(dataclasses.asdict(b))
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        assert x.dtype == y.dtype and x.shape == y.shape
        assert (x == y).all(), "ring scatter mode changed simulation state"
