"""Simulator correctness: single-flow ideality, conservation, routing
behavior, failover, and topology invariants. All runs are tiny (fast)."""
import dataclasses

import numpy as np
import pytest

from repro.netsim import fluid, metrics, paths, topo
from repro.netsim.experiment import ExpSpec, build_experiment, run_experiment
from repro.netsim.fluid import SimConfig
from repro.traffic.gen import FlowSet


def _single_flow_setup(size=1e6, cap=100, delay=5000):
    t = topo.parallel_paths(caps=(cap,), delays_us=(delay,))
    table = paths.build_path_table(t, [(0, 2)])
    fluid.attach_link_caps(table, t)
    flows = FlowSet(arrival_us=np.array([1000], np.int64),
                    size_bytes=np.array([size]),
                    pair_id=np.array([0], np.int32),
                    flow_id=np.array([42], np.uint32))
    return table, flows


@pytest.mark.parametrize("policy", ["lcmp", "ecmp"])
def test_single_flow_fct_close_to_ideal(policy):
    table, flows = _single_flow_setup()
    cfg = SimConfig(policy=policy, horizon_us=200_000, cap_scale=1.0)
    arrs, st = fluid.build(table, flows, cfg)
    final = fluid.run(arrs, st, cfg)
    stats = metrics.fct_stats(final, table, flows, cfg)
    assert stats.completed == 1
    # alone in the network: slowdown within discretization error of ideal
    assert stats.p50 < 1.1, stats.p50


def test_flow_bytes_conservation():
    """Served bytes on the first-hop link ~= flow size (fluid accounting)."""
    table, flows = _single_flow_setup(size=5e6)
    cfg = SimConfig(policy="ecmp", horizon_us=300_000, cap_scale=1.0)
    arrs, st = fluid.build(table, flows, cfg)
    final = fluid.run(arrs, st, cfg)
    first = int(table.path_first[0])
    served = float(final.serv_bytes[first])
    assert abs(served - 5e6) / 5e6 < 0.05


def test_link_never_overserved():
    spec = ExpSpec(topology="testbed8", load=0.8, policy="ecmp",
                   duration_us=150_000)
    stats, util, _ = run_experiment(spec)
    assert (util <= 1.0 + 1e-6).all()


def test_lcmp_beats_baselines_at_30pct():
    """The paper's headline (Fig. 5 direction): LCMP lowers both median and
    tail FCT slowdown vs ECMP and UCMP on the 8-DC testbed at 30% load."""
    res = {}
    for pol in ["ecmp", "ucmp", "lcmp"]:
        spec = ExpSpec(topology="testbed8", load=0.3, policy=pol,
                       duration_us=400_000, seed=7)
        stats, _, _ = run_experiment(spec)
        res[pol] = stats
    assert res["lcmp"].p50 < res["ecmp"].p50
    assert res["lcmp"].p50 < res["ucmp"].p50
    assert res["lcmp"].p99 < res["ecmp"].p99
    assert res["lcmp"].p99 < res["ucmp"].p99


def test_ucmp_concentrates_ecmp_spreads_lcmp_avoids_slow():
    """Fig. 1b placement patterns."""
    longhaul = [0, 4, 8, 12, 16, 20]      # DC1->DC2..DC7 long-haul links
    utils = {}
    for pol in ["ecmp", "ucmp", "lcmp"]:
        spec = ExpSpec(topology="testbed8", load=0.3, policy=pol,
                       duration_us=300_000, seed=3)
        _, util, _ = run_experiment(spec)
        utils[pol] = util[longhaul]
    # UCMP: only the two 200G paths (idx 0,1) carry traffic
    assert utils["ucmp"][2:].max() < 0.01
    assert utils["ucmp"][:2].min() > 0.02
    # ECMP: every path carries traffic, including both 250ms ones
    assert utils["ecmp"].min() > 0.01
    # LCMP: the 250 ms paths (DC2 idx 0, DC7 idx 5) stay empty
    assert utils["lcmp"][0] < 0.01 and utils["lcmp"][5] < 0.01


def test_failover_rehashes_and_completes():
    """Kill the 100G/5ms long-haul link mid-run: pinned flows must re-hash
    (lazy fast-failover) and still complete; nothing re-lands on it."""
    spec = ExpSpec(topology="testbed8", load=0.3, policy="lcmp",
                   duration_us=300_000, seed=5)
    t, table, flows, cfg = build_experiment(spec)
    cfg = dataclasses.replace(cfg, fail_link=12, fail_at_us=100_000)
    arrs, st = fluid.build(table, flows, cfg)
    final = fluid.run(arrs, st, cfg)
    done = np.asarray(final.done)
    assert done.mean() > 0.95
    # flows finishing after the failure cannot be on a path through link 12
    path = np.asarray(final.flow_path)
    uses12 = np.asarray((arrs.path_links == 12).any(-1))[np.maximum(path, 0)]
    fct_end = np.asarray(final.fct_us) + flows.arrival_us
    late = done & (flows.arrival_us > 100_000)
    assert not uses12[late].any()


def test_bso13_multipath_fraction_near_paper():
    t = topo.bso_13dc()
    table = paths.build_path_table(t, paths.all_pairs(t))
    frac = paths.multipath_pair_fraction(table)
    # paper: 20/78 = 25.6%; our stand-in is tuned to 26.3%
    assert 0.20 <= frac <= 0.32, frac


def test_path_table_invariants():
    t = topo.testbed_8dc()
    table = paths.build_path_table(t, [(0, 7)])
    assert table.pair_ncand[0] == 6           # six candidate routes
    firsts = table.path_first[table.pair_cand[0, :6]]
    assert len(set(firsts.tolist())) == 6     # distinct first hops
    # prop = sum of hop delays; cap = bottleneck
    _, _, cap_a, del_a = t.arrays()
    for p in range(table.num_paths):
        hops = table.path_links[p][table.path_links[p] >= 0]
        assert table.path_prop_us[p] == del_a[hops].sum()
        assert table.path_cap[p] == cap_a[hops].min()


@pytest.mark.parametrize("cc", ["dcqcn", "dctcp", "timely", "hpcc"])
def test_cc_variants_run_and_complete(cc):
    spec = ExpSpec(topology="testbed8", load=0.3, policy="lcmp", cc=cc,
                   duration_us=200_000, seed=2)
    stats, _, _ = run_experiment(spec)
    assert stats.completed / stats.offered > 0.9
    assert np.isfinite(stats.p50)
