"""Fast 1-device tests for the ``repro.dist`` layer (the 8-device
subprocess contract lives in tests/test_dist.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.dist import compress
from repro.dist import lcmp_collectives as lc
from repro.dist.mesh_rules import Rules, axis_sizes_of, make_rules
from repro.models.arch import init_params

AXES = {"data": 2, "model": 4}


# ----------------------------------------------------------- mesh rules
@pytest.mark.parametrize("arch", ["qwen3_4b", "mixtral_8x7b",
                                  "falcon_mamba_7b", "zamba2_1p2b",
                                  "whisper_medium", "internvl2_2b"])
def test_param_specs_cover_every_leaf_and_divide(arch):
    cfg = configs.get(arch, smoke=True)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    specs = Rules(cfg, AXES).param_specs(params)
    pl = jax.tree.leaves(params)
    sl = jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
    assert len(pl) == len(sl)
    for leaf, spec in zip(pl, sl):
        assert isinstance(spec, P) and len(spec) <= leaf.ndim
        named = [a for a in spec if a is not None]
        assert len(set(named)) == len(named)          # no axis used twice
        for d, ax in enumerate(spec):
            if ax is not None:
                assert leaf.shape[d] % AXES[ax] == 0  # always placeable


def test_param_specs_tp_on_big_matmuls():
    cfg = configs.get("qwen3_4b", smoke=True)
    params = jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))
    specs = Rules(cfg, AXES).param_specs(params)
    attn = specs["layers"]["attn"]
    assert attn["wq"][-1] == "model" and attn["wo"][-2] == "model"
    assert specs["layers"]["mlp"]["w_up"][-1] == "model"
    assert specs["embed"][0] == "model"
    # stacked layer axis never sharded
    assert attn["wq"][0] is None


def test_batch_specs_and_axis_sizes_roundtrip():
    cfg = configs.get("qwen3_4b", smoke=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert axis_sizes_of(mesh) == {"data": 1, "model": 1}
    rules = make_rules(cfg, mesh)
    bs = rules.train_batch_specs(8, 32)
    assert set(bs) >= {"tokens", "labels"}
    # pod axis joins data parallelism for inputs; indivisible batch -> replicate
    r2 = Rules(cfg, {"pod": 2, "data": 2, "model": 1})
    assert r2.train_batch_specs(8, 32)["tokens"][0] == ("pod", "data")
    assert r2.train_batch_specs(6, 32)["tokens"][0] is None
    assert r2.decode_token_spec(8)[0] == ("pod", "data")


# ------------------------------------------------------- lcmp pod reduce
def test_pod_reduce_noop_without_pod_axis():
    tree = {"a": jnp.arange(8.0), "b": jnp.ones((3, 5))}
    out = lc.lcmp_pod_reduce(tree, "pod")         # axis unbound: identity
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a is b
    assert lc.lcmp_pod_reduce(tree, None) is tree
    out_jit = jax.jit(lambda t: lc.lcmp_pod_reduce(t, "pod"))(tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out_jit)):
        assert np.allclose(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- compress
def test_compress_roundtrip_error_within_one_step():
    x = jax.random.normal(jax.random.key(0), (4096,))
    w = compress.encode(x, seed=3)
    y = compress.decode(w)
    step = float(jnp.max(w.scales))               # one quantization step
    assert y.shape == x.shape
    assert float(jnp.max(jnp.abs(y - x))) <= step + 1e-7
    assert compress.wire_bytes(w) < 0.3 * 4 * x.size   # ~4x fewer bytes


def test_compress_handles_unaligned_length_and_ef_identity():
    x = jax.random.normal(jax.random.key(1), (1500,))  # not a BLOCK multiple
    w = compress.encode(x, seed=5)
    assert compress.decode(w).shape == x.shape
    wef, resid = compress.encode_ef(x, jnp.zeros_like(x), seed=5)
    np.testing.assert_allclose(np.asarray(compress.decode(wef) + resid),
                               np.asarray(x), atol=1e-6)


# ------------------------------------------------- route scheduling/telemetry
@pytest.fixture
def fresh_telemetry():
    lc._TELEMETRY.reset()
    yield lc._TELEMETRY
    lc._TELEMETRY.reset()


def test_schedule_buckets_keeps_low_cost_half(fresh_telemetry):
    ids = lc._fmix32_host(np.arange(64, dtype=np.uint32))
    routes = lc.schedule_buckets(ids)
    cost = lc.ALPHA * lc.C_PATH + lc.BETA * fresh_telemetry.cong_scores()
    kept = set(np.argsort(cost, kind="stable")[: (lc.NUM_ROUTES + 1) // 2])
    assert set(routes.tolist()) <= kept
    np.testing.assert_array_equal(routes, lc.schedule_buckets(ids))  # sticky


def test_schedule_buckets_skips_dead_routes(fresh_telemetry):
    ids = lc._fmix32_host(np.arange(64, dtype=np.uint32))
    alive = np.ones(lc.NUM_ROUTES, bool)
    alive[lc.schedule_buckets(ids)[0]] = False    # kill a chosen route
    lc.set_route_liveness(alive)
    assert not set(lc.schedule_buckets(ids).tolist()) & set(
        np.nonzero(~alive)[0].tolist())
    lc.set_route_liveness(np.zeros(lc.NUM_ROUTES, bool))
    assert (lc.schedule_buckets(ids) == -1).all()


def test_telemetry_straggler_trend_raises_cong_score(fresh_telemetry):
    tm = fresh_telemetry
    base = tm.cong_scores().copy()
    for step in range(12):                        # route 1 straggles
        tm.observe([50, 900, 50], step)
    after = tm.cong_scores()
    assert after[1] > base[1]
    assert after[1] > after[0] and after[1] > after[2]


def test_observe_measured_demotes_persistently_slow_route(fresh_telemetry,
                                                          monkeypatch):
    """The cosim feedback seam: feeding externally *measured* per-bucket
    wall times (route 1 persistently slow) raises its congestion score
    until ``schedule_buckets`` drops it from the low-cost half — the
    demotion the synthetic wall clock used to drive now follows the
    measurement plane. C_PATH is flattened to isolate the congestion
    term: the stock three routes' static-cost spread (42/270/546 fused)
    exceeds the 255-capped C_cong by design, so among THOSE routes
    telemetry reorders preference inside the kept set but never evicts —
    eviction needs near-tied static costs, which is what equal-cost
    parallel hauls present."""
    monkeypatch.setattr(lc, "C_PATH", np.zeros_like(lc.C_PATH))
    tm = fresh_telemetry
    ids = lc._fmix32_host(np.arange(64, dtype=np.uint32))
    assert 1 in set(lc.schedule_buckets(ids).tolist())   # kept while quiet
    for step in range(12):
        tm.observe_measured(np.array([50, 900, 50, 880], np.int64),
                            np.array([0, 1, 2, 1], np.int64), step)
    scores = tm.cong_scores()
    assert scores[1] > scores[0] and scores[1] > scores[2]
    assert 1 not in set(lc.schedule_buckets(ids).tolist())


def test_observe_measured_semantics(fresh_telemetry):
    """Per-route sample = MAX over that route's buckets (barrier: the
    straggler bucket is the route's observed time); routes with no
    bucket this step hold their last sample; slot -1 buckets (routes the
    telemetry does not register) are dropped; shape mismatches raise."""
    tm = fresh_telemetry
    tm.observe([100, 100, 100], step=0)
    tm.observe_measured(np.array([200, 700, 33], np.int64),
                        np.array([1, 1, -1], np.int64), step=1)
    assert tm.cur.tolist() == [100, 700, 100]
    with pytest.raises(ValueError):
        tm.observe_measured(np.array([1, 2], np.int64),
                            np.array([0], np.int64), step=2)
