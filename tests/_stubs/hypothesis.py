"""Minimal stand-in for the slice of the ``hypothesis`` API this suite
uses (``given``, ``settings``, ``strategies.integers/lists``).

NOT a property-testing engine — no shrinking, no example database, no
coverage guidance. Each ``@given`` test runs ``max_examples`` times:
example 0 is all-minimum bounds, example 1 all-maximum bounds, the rest
are uniform draws from a PRNG seeded by the test's qualified name (fully
deterministic across runs). Only loaded via tests/conftest.py when the
real ``hypothesis`` (requirements-dev.txt) is not importable.
"""
from __future__ import annotations

import functools
import inspect
import random

DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def min_example(self):
        raise NotImplementedError

    def max_example(self):
        raise NotImplementedError

    def example(self, rng: random.Random):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def min_example(self):
        return self.lo

    def max_example(self):
        return self.hi

    def example(self, rng):
        return rng.randint(self.lo, self.hi)


class _Floats(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = lo, hi

    def min_example(self):
        return self.lo

    def max_example(self):
        return self.hi

    def example(self, rng):
        return rng.uniform(self.lo, self.hi)


class _Booleans(_Strategy):
    def min_example(self):
        return False

    def max_example(self):
        return True

    def example(self, rng):
        return rng.random() < 0.5


class _SampledFrom(_Strategy):
    def __init__(self, elements):
        self.elements = list(elements)

    def min_example(self):
        return self.elements[0]

    def max_example(self):
        return self.elements[-1]

    def example(self, rng):
        return rng.choice(self.elements)


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=None):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10

    def min_example(self):
        return [self.elem.min_example()] * max(self.min_size, 1) \
            if self.min_size else []

    def max_example(self):
        return [self.elem.max_example()] * self.max_size

    def example(self, rng):
        k = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng) for _ in range(k)]


class _StrategiesNamespace:
    @staticmethod
    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value=0.0, max_value=1.0, **_):
        return _Floats(min_value, max_value)

    @staticmethod
    def booleans():
        return _Booleans()

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(elements)

    @staticmethod
    def lists(elements, min_size=0, max_size=None, **_):
        return _Lists(elements, min_size, max_size)


strategies = _StrategiesNamespace()


class settings:  # noqa: N801 (mirrors hypothesis' lowercase class)
    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._stub_max_examples = self.max_examples
        return fn


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper():
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(fn.__qualname__)
            for i in range(n):
                if i == 0:
                    args = [s.min_example() for s in strats]
                elif i == 1:
                    args = [s.max_example() for s in strats]
                else:
                    args = [s.example(rng) for s in strats]
                fn(*args)

        # pytest must see a zero-arg test, not the wrapped signature
        # (else the strategy parameters look like missing fixtures)
        wrapper.__dict__.pop("__wrapped__", None)
        wrapper.__signature__ = inspect.Signature()
        return wrapper

    return deco
